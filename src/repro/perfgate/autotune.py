"""Tile-size autotuner: sweep Pallas grid/block shapes, pin the winners.

``python -m repro.perfgate tune [--only KERNEL,...] [--quick]`` times each
registered kernel's candidate configs on a representative workload, picks
the argmin, and persists ``results/TUNED_tiles.json`` through
:mod:`repro.kernels.tuning` — from then on the ops-layer wrappers load
the pinned shapes for this device automatically (hardcoded tiles stay
the fallback for every other machine).

The registry is an extension point: :func:`register_tunable` a new
:class:`KernelTunable` (name, candidate space, workload factory, timing
closure) and it rides the same CLI, JSON schema, and fallback rules.
Candidate spaces are full cross-products of small per-parameter option
lists — tens of configs, not thousands; this is a measured sweep, not a
search heuristic.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.kernels import ops, tuning
from repro.kernels.auction_lap import (
    auction_lap_collapsed_pallas,
    auction_lap_pallas,
)
from repro.kernels.gf2_reduce import gf2_reduce_batch_pallas
from repro.kernels.hamming import hamming_scan_pallas
from repro.kernels.pairwise_gram import pairwise_l1_pallas
from repro.kernels.sinkhorn_lse import sinkhorn_lse_pallas


def _timed(fn, *args, repeats: int = 2, **kwargs) -> float:
    """Best-of-``repeats`` seconds with a warmup call (excludes compile)."""
    out = fn(*args, **kwargs)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


@dataclasses.dataclass(frozen=True)
class KernelTunable:
    """One sweepable kernel.

    ``space`` maps parameter name → candidate values (the sweep is the
    cross product).  ``make_workload(quick)`` builds the representative
    inputs once per sweep; ``time_config(workload, config, repeats)``
    returns seconds for one candidate.  ``workload_desc`` labels the
    JSON entry so a reader knows what shape the winner was measured at.
    """

    name: str
    space: dict[str, tuple]
    make_workload: Callable[[bool], Any]
    time_config: Callable[[Any, dict, int], float]
    workload_desc: Callable[[bool], str]


TUNABLES: dict[str, KernelTunable] = {}


def register_tunable(t: KernelTunable, overwrite: bool = False) -> KernelTunable:
    if not overwrite and t.name in TUNABLES:
        raise ValueError(f"tunable {t.name!r} already registered")
    bad = set(t.space) - set(tuning.DEFAULT_TILES.get(t.name, t.space))
    if bad:
        raise ValueError(
            f"tunable {t.name!r} sweeps params {sorted(bad)} that "
            f"kernels.tuning.DEFAULT_TILES does not declare")
    TUNABLES[t.name] = t
    return t


def sweep(t: KernelTunable, quick: bool = True,
          repeats: int = 2) -> dict:
    """Time every candidate config; return the winner + full trace."""
    workload = t.make_workload(quick)
    names = list(t.space)
    candidates = []
    for values in itertools.product(*(t.space[n] for n in names)):
        config = dict(zip(names, values))
        seconds = t.time_config(workload, config, repeats)
        candidates.append({"config": config, "seconds": seconds})
    best = min(candidates, key=lambda c: c["seconds"])
    return {
        "tiles": best["config"],
        "seconds": round(best["seconds"], 6),
        "workload": t.workload_desc(quick),
        "candidates": len(candidates),
        "sweep": [{"config": c["config"],
                   "seconds": round(c["seconds"], 6)}
                  for c in candidates],
    }


def tune(only: list[str] | None = None, quick: bool = True,
         repeats: int = 2, path: str | None = None,
         save: bool = True) -> dict:
    """Sweep the registered kernels; persist winners to TUNED_tiles.json."""
    keys = list(only) if only else list(TUNABLES)
    unknown = [k for k in keys if k not in TUNABLES]
    if unknown:
        raise SystemExit(
            f"unknown tunables {unknown}; known: {sorted(TUNABLES)}")
    winners = {}
    for k in keys:
        print(f"[perfgate] tuning {k} "
              f"({len(list(itertools.product(*TUNABLES[k].space.values())))} "
              f"configs)", flush=True)
        winners[k] = sweep(TUNABLES[k], quick=quick, repeats=repeats)
        print(f"[perfgate] {k}: winner {winners[k]['tiles']} "
              f"at {winners[k]['seconds']:.4g}s "
              f"({winners[k]['workload']})", flush=True)
    report = {"kernels": winners, "device": tuning.device_string(),
              "quick": quick}
    if save:
        from benchmarks.common import git_rev

        out = tuning.save_tuned(
            winners, path=path,
            meta={"generated_by": "python -m repro.perfgate tune",
                  "git_rev": git_rev(), "quick": quick})
        report["path"] = out
        print(f"[perfgate] wrote {out}")
    return report


# ------------------------------------------------------------- the kernels

def _interp() -> bool:
    return jax.default_backend() != "tpu"


def _gram_workload(quick: bool):
    m, d = (64, 256) if quick else (256, 512)
    x = jax.random.normal(jax.random.PRNGKey(7), (m, d), jnp.float32)
    return x


register_tunable(KernelTunable(
    name="pairwise_gram",
    space={"tile_m": (8, 16, 32), "tile_n": (128, 256),
           "tile_d": (128, 256)},
    make_workload=_gram_workload,
    time_config=lambda x, c, r: _timed(
        pairwise_l1_pallas, x, x, interpret=_interp(), repeats=r, **c),
    workload_desc=lambda q: "G64_D256" if q else "G256_D512",
))


def _hamming_workload(quick: bool):
    # packed 128-bit codes (W=4 words): the TopoIndex default; corpus size
    # is the axis that matters — the scan is O(N·W) per query row
    q, n = (16, 4096) if quick else (16, 32768)
    ks = jax.random.split(jax.random.PRNGKey(17), 2)
    cq = jax.random.randint(ks[0], (q, 4), 0, 1 << 30).astype(jnp.uint32)
    cd = jax.random.randint(ks[1], (n, 4), 0, 1 << 30).astype(jnp.uint32)
    mq = jnp.full((q, 4), 0xFFFFFFFF, jnp.uint32)
    return cq, mq, cd


register_tunable(KernelTunable(
    name="hamming",
    space={"tile_q": (8, 16, 32), "tile_n": (128, 256, 512)},
    make_workload=_hamming_workload,
    time_config=lambda w, c, r: _timed(
        hamming_scan_pallas, *w, interpret=_interp(), repeats=r, **c),
    workload_desc=lambda q: "Q16_N4096_W4" if q else "Q16_N32768_W4",
))


def _sinkhorn_workload(quick: bool):
    from repro.metrics.distances import _cloud_planes

    b, m = (2, 256) if quick else (4, 512)
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    x = jax.random.normal(ks[0], (b, m, 2), jnp.float32)
    y = jax.random.normal(ks[1], (b, m, 2), jnp.float32)
    flags = jnp.arange(m) >= m // 2
    dual = jax.random.normal(ks[2], (b, m), jnp.float32)
    logw = jnp.zeros((b, m), jnp.float32)
    e_t = jnp.full((b, 1), 0.5, jnp.float32)
    return (_cloud_planes(x, flags), _cloud_planes(y, flags), dual, logw,
            e_t)


register_tunable(KernelTunable(
    name="sinkhorn_lse",
    space={"tile": (64, 128, 256)},
    make_workload=_sinkhorn_workload,
    time_config=lambda w, c, r: _timed(
        sinkhorn_lse_pallas, *w, tile_m=c["tile"], tile_n=c["tile"],
        interpret=_interp(), repeats=r),
    workload_desc=lambda q: "B2_M256" if q else "B4_M512",
))


def _auction_workload(quick: bool):
    b, m = (8, 16) if quick else (32, 16)
    return jax.random.uniform(jax.random.PRNGKey(9), (b, m, m),
                              jnp.float32, 0.0, 5.0)


register_tunable(KernelTunable(
    name="auction_lap",
    space={"tile_b": (1, 2, 4, 8)},
    make_workload=_auction_workload,
    time_config=lambda c3, c, r: _timed(
        auction_lap_pallas, c3, tile_b=c["tile_b"], interpret=_interp(),
        repeats=r),
    workload_desc=lambda q: "B8_M16" if q else "B32_M16",
))


def _collapsed_workload(quick: bool):
    # random reduced-cost problems (cbar = pp − diag1 − diag2 over valid
    # slots) plus the equivalent expanded (2K)² matrices, so the sweep can
    # time the collapse="on"/"off" formulations on the same instances.
    # Half the point costs are quantized to a handful of levels: graph
    # persistence diagrams are tie-heavy (integer filtration values), and
    # ties are what make an over-eager fwd/rev interleave ping-pong — a
    # config must survive them to win the sweep
    b, k = (8, 16) if quick else (32, 16)
    ks = jax.random.split(jax.random.PRNGKey(21), 4)
    pp = jax.random.uniform(ks[0], (b, k, k), jnp.float32, 0.0, 4.0)
    pp = pp.at[b // 2:].set(jnp.round(pp[b // 2:] * 2.0) / 2.0)
    d1 = jax.random.uniform(ks[1], (b, k), jnp.float32, 0.0, 2.0)
    d2 = jax.random.uniform(ks[2], (b, k), jnp.float32, 0.0, 2.0)
    nreal = jax.random.randint(ks[3], (b, 2), k // 2, k + 1)
    idx = jnp.arange(k)
    keep1 = idx[None, :] < nreal[:, :1]
    keep2 = idx[None, :] < nreal[:, 1:]
    valid = keep1[:, :, None] & keep2[:, None, :]
    cbar = jnp.where(valid, pp - d1[:, :, None] - d2[:, None, :], 0.0)
    big = 1e6
    eye = jnp.eye(k, dtype=bool)[None]
    tl = jnp.where(valid, pp, big)
    tr = jnp.where(eye, jnp.where(keep1, d1, 0.0)[:, :, None], big)
    bl = jnp.where(eye, jnp.where(keep2, d2, 0.0)[:, None, :], big)
    br = jnp.zeros((b, k, k), jnp.float32)
    expanded = jnp.concatenate(
        [jnp.concatenate([tl, tr], axis=-1),
         jnp.concatenate([bl, br], axis=-1)], axis=-2)
    return cbar, keep1, keep2, expanded


def _time_collapsed(w, config, repeats):
    cbar, keep1, keep2, expanded = w
    if config["collapse"] == "off":
        # the legacy expanded path ignores rev_every (forward-only solver)
        return _timed(auction_lap_pallas, expanded, tile_b=config["tile_b"],
                      interpret=_interp(), repeats=repeats)
    t = _timed(
        auction_lap_collapsed_pallas, cbar, keep1, keep2,
        jnp.zeros_like(cbar[..., 0]), tile_b=config["tile_b"],
        rev_every=config["rev_every"], interpret=_interp(), repeats=repeats)
    # a config that trades convergence for wall time is disqualified — an
    # unconverged lane means uncertified (possibly wrong) distances and a
    # price the serve-level warm-start cache must refuse to store
    _, _, conv, _, _ = auction_lap_collapsed_pallas(
        cbar, keep1, keep2, jnp.zeros_like(cbar[..., 0]),
        tile_b=config["tile_b"], rev_every=config["rev_every"],
        interpret=_interp())
    if not bool(jnp.all(conv)):
        return float("inf")
    return t


register_tunable(KernelTunable(
    name="auction_collapsed",
    space={"tile_b": (1, 2, 4), "rev_every": (0, 2, 8),
           "collapse": ("on", "off")},
    make_workload=_collapsed_workload,
    time_config=_time_collapsed,
    workload_desc=lambda q: "B8_K16" if q else "B32_K16",
))


def _gf2_workload(quick: bool):
    # random strictly-lower-triangular packed matrices: GF(2) elimination
    # terminates on any matrix (each XOR strictly lowers the pivot row),
    # and random fill is the worst case for XOR chain length
    b, s = (4, 64) if quick else (16, 128)
    w = -(-s // 32)
    bits = jax.random.randint(
        jax.random.PRNGKey(13), (b, s, w), 0, 1 << 16)
    row = jnp.arange(s)[None, :, None]
    word = jnp.arange(w)[None, None, :]
    below = jnp.where(row // 32 > word, -1,
                      jnp.where(row // 32 == word, (1 << (row % 32)) - 1,
                                0))
    return (bits & below).astype(jnp.uint32)


def _time_gf2(b3, config, repeats):
    mode = config["batch_mode"]
    if mode == "grid":
        return _timed(lambda x: gf2_reduce_batch_pallas(
            x, interpret=_interp()), b3, repeats=repeats)
    return _timed(
        jax.jit(jax.vmap(lambda bb: ops.gf2_reduce(bb))), b3,
        repeats=repeats)


register_tunable(KernelTunable(
    name="gf2_reduce",
    space={"batch_mode": ("vmap", "grid")},
    make_workload=_gf2_workload,
    time_config=_time_gf2,
    workload_desc=lambda q: "B4_S64" if q else "B16_S128",
))
