"""The gate runner: fresh benchmark rows vs the committed reference store.

``python -m repro.perfgate check [--only SUITE,...] [--quick]`` executes
benchmark suites through the existing ``benchmarks/run.py`` registry,
diffs every fresh ``(benchmark, metric)`` row against the reference store
(:mod:`repro.perfgate.references`), attributes each regression to a cost
cell (:mod:`repro.perfgate.cost_cells`), writes a machine-readable
``results/GATE_report.json`` next to the ``BENCH_*.json`` baselines, and
exits nonzero when anything regressed past its band.

Quick-vs-full semantics: a row only gates on a *relative* band (``lower``
/ ``higher`` directions) when the fresh run's ``--quick`` flag matches
the baseline's — quick suites shrink their workloads, so "pairs/s at
quick size" is not comparable to the committed full-run number.  Rows
whose workload is quick-invariant (the kernel microbenches) gate either
way because their suite declares fixed sizes.  ``abs_upper`` correctness
counters (parity failures, max-abs-diffs) gate regardless of size.

The gate itself never rewrites the ``BENCH_*.json`` baselines — suites
run through an in-memory :class:`benchmarks.common.Report`; refreshing a
baseline stays an explicit ``python -m benchmarks.run`` + commit.
"""
from __future__ import annotations

import json
import os
import time
import traceback

from repro.perfgate import cost_cells
from repro.perfgate.references import PerfReference, load_reference_store

GATE_REPORT = "GATE_report.json"


# ---------------------------------------------------------------- row diff

def evaluate_row(ref: PerfReference, value: float, band_scale: float = 1.0,
                 quick_mismatch: bool = False) -> dict:
    """Verdict for one matched row: ok / regression / improvement / info."""
    rec = {
        "benchmark": ref.benchmark, "metric": ref.metric,
        "value": value, "ref": ref.value, "direction": ref.direction,
        "band": ref.rel_band, "band_scale": band_scale,
        "source": ref.source,
    }
    if ref.direction == "info":
        rec["status"] = "info"
        return rec
    if ref.direction == "abs_upper":
        # correctness counter: never loosened by band_scale
        allowed = max(ref.value * 2.0, ref.abs_tol)
        rec["allowed"] = allowed
        rec["status"] = "ok" if value <= allowed else "regression"
        return rec
    if quick_mismatch:
        # workload size differs from the baseline's -> not comparable
        rec["status"] = "info_quick_mismatch"
        return rec
    band = min(ref.rel_band * band_scale, 0.95 if ref.direction == "higher"
               else 100.0)
    rec["band_scaled"] = band
    if ref.direction == "lower":
        rec["allowed"] = ref.value * (1.0 + band)
        if value > rec["allowed"]:
            rec["status"] = "regression"
        elif value < ref.value * (1.0 - band):
            rec["status"] = "improvement"
        else:
            rec["status"] = "ok"
    else:  # higher
        rec["allowed"] = ref.value * (1.0 - band)
        if value < rec["allowed"]:
            rec["status"] = "regression"
        elif value > ref.value * (1.0 + band):
            rec["status"] = "improvement"
        else:
            rec["status"] = "ok"
    if rec["status"] == "regression":
        denom = max(abs(ref.value), 1e-12)
        rec["rel_change"] = (value - ref.value) / denom
    return rec


def diff_rows(suite: str, rows, refs: dict, band_scale: float = 1.0,
              fresh_quick: bool = False,
              quick_invariant: bool = False) -> dict:
    """Diff one suite's fresh ``(benchmark, metric, value)`` rows.

    ``refs``: ``{(benchmark, metric): PerfReference}`` for this suite.
    Returns the per-suite report block: regressions (with cost cells),
    improvements, per-status counts, unreferenced fresh rows and stale
    references (baseline rows the fresh run no longer produced).
    ``quick_invariant`` suites gate relative bands even across a
    quick-flag mismatch (their workload sizes don't change).
    """
    regressions, improvements, unreferenced = [], [], []
    counts = {"ok": 0, "info": 0, "info_quick_mismatch": 0}
    seen = set()
    for (bench, metric, value) in rows:
        ref = refs.get((bench, metric))
        if ref is None:
            unreferenced.append(f"{bench}.{metric}")
            continue
        seen.add((bench, metric))
        mismatch = (not quick_invariant) and (fresh_quick != ref.quick)
        rec = evaluate_row(ref, float(value), band_scale,
                           quick_mismatch=mismatch)
        status = rec["status"]
        if status == "regression":
            rec["cost_cell"] = cost_cells.attribute(suite, bench, metric)
            regressions.append(rec)
        elif status == "improvement":
            improvements.append(rec)
        else:
            counts[status] = counts.get(status, 0) + 1
    stale = sorted(f"{b}.{m}" for (b, m) in set(refs) - seen)
    return {
        "suite": suite,
        "gated_ok": counts.get("ok", 0),
        "info": counts.get("info", 0) + counts.get("info_quick_mismatch", 0),
        "quick_mismatched": counts.get("info_quick_mismatch", 0),
        "regressions": regressions,
        "improvements": improvements,
        "unreferenced": unreferenced,
        "stale_refs": stale,
    }


# ---------------------------------------------------------------- execution

def _suite_registry():
    """The benchmark suite registry (imported lazily: ``benchmarks`` lives
    at the repo root, not inside the ``repro`` package)."""
    from benchmarks import run as brun
    return brun


def run_suite(key: str, quick: bool) -> dict:
    """Execute one registered suite in-memory; never writes BENCH JSONs."""
    from benchmarks.common import Report, telemetry_delta, telemetry_snapshot

    brun = _suite_registry()
    suite = brun.SUITES[key]
    report = Report(quick=quick)
    t0 = time.time()
    tele0 = telemetry_snapshot()
    ok, error = True, None
    try:
        mod = __import__(suite.module, fromlist=["run"])
        brun._call_suite(mod, report, quick)
    except Exception:
        ok = False
        error = traceback.format_exc(limit=20)
        traceback.print_exc()
    # mirror benchmarks/run.py's telemetry rows: baselines carry
    # "telemetry.*" rows, so a gate run must produce them too or every
    # check would flag them as stale references
    for metric, value in sorted(telemetry_delta(tele0).items()):
        report.add("telemetry", metric, value)
    return {"rows": report.rows, "wall_s": time.time() - t0,
            "ok": ok, "error": error}


def check(only: list[str] | None = None, quick: bool = False,
          band_scale: float = 1.0, results_dir: str = "results",
          out: str | None = None, runner=run_suite) -> dict:
    """Run the gate; returns the full report dict (``report["ok"]`` is the
    pass/fail verdict, mirrored in the CLI exit code).

    ``runner(key, quick) -> {"rows", "wall_s", "ok", "error"}`` is
    injectable so tests can gate synthetic rows without timing anything.
    """
    from benchmarks.common import git_rev

    brun = _suite_registry()
    keys = list(only) if only else list(brun.SUITES)
    unknown = [k for k in keys if k not in brun.SUITES]
    if unknown:
        raise SystemExit(
            f"unknown suites {unknown}; known: {list(brun.SUITES)}")
    store = load_reference_store(
        results_dir, {k: brun.SUITES[k].references for k in keys})

    suites_out, failed, total_regressions = {}, [], 0
    for k in keys:
        print(f"[perfgate] {k}: {brun.SUITES[k].description}", flush=True)
        res = runner(k, quick)
        block = diff_rows(
            k, res["rows"], store.get(k, {}), band_scale=band_scale,
            fresh_quick=quick,
            quick_invariant=getattr(brun.SUITES[k], "quick_invariant",
                                    False))
        block.update(wall_s=round(res["wall_s"], 4), suite_ok=res["ok"],
                     error=res["error"], n_rows=len(res["rows"]),
                     n_refs=len(store.get(k, {})))
        if not res["ok"]:
            failed.append(k)
        total_regressions += len(block["regressions"])
        suites_out[k] = block
        _print_suite(block)

    # TopoWatch SLO verdicts at gate time: which objectives were installed,
    # their current status, and the cumulative breach counter (whose
    # per-run delta is ALSO gated abs_upper via telemetry.slo_breaches_total)
    from repro.obs.slo import verdict_block

    report = {
        "schema": 1,
        "generated_by": "python -m repro.perfgate check",
        "git_rev": git_rev(),
        "quick": quick,
        "band_scale": band_scale,
        "suites": suites_out,
        "failed_suites": failed,
        "total_regressions": total_regressions,
        "slo": verdict_block(),
        "ok": not failed and total_regressions == 0,
    }
    out = out or os.path.join(results_dir, GATE_REPORT)
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"[perfgate] wrote {out}")
    _print_verdict(report)
    return report


# ---------------------------------------------------------------- reporting

def _print_suite(block: dict) -> None:
    s = block["suite"]
    print(f"[perfgate] {s}: {block['gated_ok']} gated ok, "
          f"{len(block['regressions'])} regressed, "
          f"{len(block['improvements'])} improved, "
          f"{block['info']} info, "
          f"{len(block['unreferenced'])} unreferenced, "
          f"{len(block['stale_refs'])} stale refs", flush=True)
    for r in block["regressions"]:
        cell = r.get("cost_cell", {})
        change = r.get("rel_change")
        moved = (f"{change:+.0%}" if change is not None
                 else f"{r['value']:.4g} > {r.get('allowed', 0):.4g}")
        print(f"  REGRESSION {r['benchmark']}.{r['metric']}: "
              f"{r['value']:.4g} vs ref {r['ref']:.4g} ({moved}, "
              f"{r['direction']}, band {r['band']:.2f}"
              f"×{r['band_scale']:g})\n"
              f"    cost cell: {cell.get('cell', '?')} "
              f"[{cell.get('bound', '?')}-bound]", flush=True)


def _print_verdict(report: dict) -> None:
    if report["ok"]:
        print("[perfgate] PASS: no regressions past their bands")
        return
    n = report["total_regressions"]
    print(f"[perfgate] FAIL: {n} regression(s)"
          + (f", failed suites: {report['failed_suites']}"
             if report["failed_suites"] else ""))
