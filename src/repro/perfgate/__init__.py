"""PerfGate: the perf-regression gate + Pallas tile autotuner.

Turns the committed ``results/BENCH_*.json`` trajectory from passive
artifacts into an enforced contract (ROADMAP: "continuous perf gate +
kernel autotuner", in the mold of the ReFrame perf-reference checks):

* :mod:`repro.perfgate.references` — parses committed baselines into
  per-metric perf references with tolerance bands (per-suite
  ``RefSpec`` declarations live next to the suite registry in
  ``benchmarks/run.py``; defaults are derived from the metric name and
  widened by the run-to-run jitter each baseline records in its
  ``deltas`` block).
* :mod:`repro.perfgate.gate` — ``python -m repro.perfgate check``: runs
  benchmark suites through the existing ``benchmarks/run.py`` registry,
  diffs fresh rows against the reference store, attributes regressions
  to a cost cell (:mod:`repro.perfgate.cost_cells`, riding
  ``launch/roofline.py``), writes ``results/GATE_report.json`` and exits
  nonzero on any regression.
* :mod:`repro.perfgate.autotune` — ``python -m repro.perfgate tune``:
  sweeps Pallas grid/block shapes per kernel, persists winners to
  ``results/TUNED_tiles.json`` (``repro.kernels.tuning`` is the loader
  the ops layer consults, hardcoded tiles staying the fallback).
"""
from repro.perfgate.references import (  # noqa: F401
    PerfReference,
    RefSpec,
    load_reference_store,
)
from repro.perfgate.gate import check, diff_rows  # noqa: F401
from repro.perfgate.autotune import TUNABLES, tune  # noqa: F401
