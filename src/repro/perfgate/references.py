"""PerfGate reference store: committed BENCH_*.json baselines → references.

Every benchmark suite leaves a ``results/BENCH_<suite>.json`` behind
(``benchmarks/common.py::write_suite_json``): rows, wall time, the
``git_rev`` it measured, and per-metric ``deltas`` against the run before
it.  This module turns those files into *perf references* — ReFrame-style
``(value, direction, tolerance band)`` records the gate can diff a fresh
run against.

Band semantics
--------------
Each reference carries a direction and a band:

* ``lower``     — lower is better (seconds, latency, bytes).  Regression
  when ``fresh > ref · (1 + band)``.
* ``higher``    — higher is better (throughput, speedup, recall, skip
  rate).  Regression when ``fresh < ref · (1 − band)``.
* ``abs_upper`` — correctness counters and parity diffs (``failed``,
  ``*_mismatches``, ``*_max_abs_diff``).  Regression when
  ``fresh > max(ref · 2, abs_tol)``; never loosened by ``--band-scale``.
* ``info``      — recorded, never gated (row counts, configuration
  echoes, quantities with no monotone "better").

Bands resolve in three layers: a suite's explicit :class:`RefSpec`
declarations (``benchmarks/run.py``) win, then a metric-name classifier
supplies defaults, and finally the observed run-to-run jitter recorded in
the baseline's ``deltas`` block widens the band to
``max(band, JITTER_MULT · |delta| / |prev|)`` — a metric that historically
moved 30% between identical-code runs must not gate at 10%.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import json
import os

# default relative bands per direction (CPU timing jitter is large; the
# gate's --band-scale multiplies these further for cold CI machines)
DEFAULT_REL_BAND = {"lower": 0.75, "higher": 0.40}
# multiplier on the observed run-to-run jitter folded into the band
JITTER_MULT = 3.0
# a band can never grow past this (a 6x-jittery metric is effectively info)
MAX_REL_BAND = 5.0
# floor for abs_upper tolerances on float parity diffs (exact-zero refs)
ABS_DIFF_FLOOR = 1e-6


@dataclasses.dataclass(frozen=True)
class RefSpec:
    """One suite-declared reference policy.

    ``pattern`` is an ``fnmatch`` glob over ``"<benchmark>.<metric>"``;
    the first matching spec in a suite's declaration list wins over the
    metric-name classifier defaults.
    """

    pattern: str
    direction: str  # "lower" | "higher" | "abs_upper" | "info"
    rel_band: float | None = None
    abs_tol: float | None = None
    note: str = ""

    def __post_init__(self):
        if self.direction not in ("lower", "higher", "abs_upper", "info"):
            raise ValueError(f"unknown direction {self.direction!r}")


@dataclasses.dataclass(frozen=True)
class PerfReference:
    """One gated metric: baseline value + resolved band."""

    suite: str
    benchmark: str
    metric: str
    value: float
    direction: str
    rel_band: float
    abs_tol: float
    jitter: float       # observed |delta|/|prev| from the baseline run
    quick: bool         # workload size the baseline was measured at
    source: str         # "spec:<pattern>" or "default"

    @property
    def key(self) -> tuple[str, str]:
        return (self.benchmark, self.metric)


# ---------------------------------------------------------------- classifier

_ABS_TOKENS = ("max_abs_diff", "max_rel_diff", "rel_diff", "_diff",
               "failed", "mismatch", "false_positives")
_HIGHER_TOKENS = ("per_s", "speedup", "recall", "skip_rate", "purity",
                  "accuracy", "converged_frac", "reduction_pct")
_INFO_TOKENS = ("checked", "graphs", "queries", "steps", "corpus",
                "indexed", "candidates", "rounds", "rungs", "rung_",
                "buckets", "batches", "bursts", "hits", "misses",
                "updates", "recompute", "mean_", "_mean", "band_")


def classify_metric(benchmark: str, metric: str) -> RefSpec:
    """Default (direction, band) policy from the metric name alone."""
    name = f"{benchmark}.{metric}".lower()
    if benchmark.startswith("telemetry") and "slo_breach" in name:
        # SLO breach transitions counted during the gate run (obs/slo.py):
        # a correctness-grade signal, not a telemetry echo — any breach
        # over a zero baseline fails the gate, and like the other
        # abs_upper counters it is never loosened by --band-scale
        return RefSpec("*", "abs_upper", abs_tol=ABS_DIFF_FLOOR,
                       note="classifier: SLO breach counter")
    if benchmark.startswith("telemetry"):
        # TopoScope counter rows stamped by benchmarks/run.py: recorded in
        # every baseline (a doubled Gram call count is visible in the diff)
        # but never gated by default — suites gate specific counters by
        # declaring an explicit RefSpec over "telemetry.<metric>"
        return RefSpec("*", "info", note="classifier: TopoScope telemetry "
                                         "counter")
    if any(t in name for t in _ABS_TOKENS):
        return RefSpec("*", "abs_upper", abs_tol=ABS_DIFF_FLOOR,
                       note="classifier: parity/correctness counter")
    if any(t in name for t in _HIGHER_TOKENS):
        return RefSpec("*", "higher", rel_band=DEFAULT_REL_BAND["higher"],
                       note="classifier: throughput/quality metric")
    if metric.endswith(("_s", "_ms")) or "latency" in name or "bytes" in name:
        return RefSpec("*", "lower", rel_band=DEFAULT_REL_BAND["lower"],
                       note="classifier: time/size metric")
    if any(t in name for t in _INFO_TOKENS):
        return RefSpec("*", "info", note="classifier: count/config echo")
    return RefSpec("*", "info", note="classifier: unrecognized metric name")


def resolve_spec(specs: tuple[RefSpec, ...], benchmark: str,
                 metric: str) -> tuple[RefSpec, str]:
    """First matching suite spec, else the classifier default."""
    name = f"{benchmark}.{metric}"
    for spec in specs:
        if fnmatch.fnmatchcase(name, spec.pattern):
            return spec, f"spec:{spec.pattern}"
    return classify_metric(benchmark, metric), "default"


# ---------------------------------------------------------------- the store

def _baseline_jitter(payload: dict) -> dict[tuple[str, str], float]:
    """Observed run-to-run relative movement per metric, from ``deltas``."""
    out: dict[tuple[str, str], float] = {}
    for d in payload.get("deltas", ()):
        prev = d.get("prev")
        if prev is None:
            continue
        denom = max(abs(float(prev)), 1e-12)
        out[(d.get("benchmark"), d.get("metric"))] = (
            abs(float(d.get("delta", 0.0))) / denom)
    return out


def load_suite_references(
    suite: str,
    path: str,
    specs: tuple[RefSpec, ...] = (),
) -> list[PerfReference]:
    """Parse one committed ``BENCH_<suite>.json`` into references.

    Missing or unparseable files yield an empty list (a suite without a
    committed baseline has nothing to gate — the gate reports it as
    unreferenced rather than failing).
    """
    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, ValueError):
        return []
    jitter = _baseline_jitter(payload)
    quick = bool(payload.get("quick", False))
    refs = []
    for row in payload.get("rows", ()):
        bench, metric = row.get("benchmark"), row.get("metric")
        if bench is None or metric is None or row.get("value") is None:
            continue
        spec, source = resolve_spec(specs, bench, metric)
        jit = jitter.get((bench, metric), 0.0)
        band = spec.rel_band
        if band is None:
            band = DEFAULT_REL_BAND.get(spec.direction, 0.0)
        band = min(max(band, JITTER_MULT * jit), MAX_REL_BAND)
        refs.append(PerfReference(
            suite=suite, benchmark=bench, metric=metric,
            value=float(row["value"]), direction=spec.direction,
            rel_band=band,
            abs_tol=(spec.abs_tol if spec.abs_tol is not None
                     else ABS_DIFF_FLOOR),
            jitter=jit, quick=quick, source=source,
        ))
    return refs


def load_reference_store(
    results_dir: str,
    suites: dict[str, tuple[RefSpec, ...]],
) -> dict[str, dict[tuple[str, str], PerfReference]]:
    """{suite: {(benchmark, metric): PerfReference}} for the given suites."""
    store = {}
    for suite, specs in suites.items():
        path = os.path.join(results_dir, f"BENCH_{suite}.json")
        refs = load_suite_references(suite, path, specs)
        store[suite] = {r.key: r for r in refs}
    return store
