"""Cost-cell attribution for gate regressions.

When the gate flags ``kernel_pairwise_gram.G128_D512_pallas_s`` as
regressed, the report should say *where the time goes*, not just that it
went up.  Each benchmark family carries a coarse cost model — FLOPs and
bytes as a function of the shape tokens embedded in the metric name
(``B32_N128``, ``G128_D512``, ``B64_M32``, ``S512``) — and the regression
is attributed to the dominant roofline term via the same
``launch/roofline.py::roofline_terms`` machinery the dry-run lowering
reports use.  Benchmarks without a closed-form model fall back to a
subsystem cell (serve drain, stream verdict, two-stage retrieval, …) so
every regression still names the layer that owns it.
"""
from __future__ import annotations

import re
from typing import Callable

from repro.launch.roofline import roofline_terms

# shape tokens: a capital letter immediately followed by digits, delimited
# by "_" (B32_N128, G64_D256, S512_...)
_TOKEN_RE = re.compile(r"(?:^|_)([A-Z])(\d+)(?=_|$)")


def parse_shape(metric: str) -> dict[str, int]:
    """{"B": 32, "N": 128} from a metric name like ``B32_N128_jnp_s``."""
    return {m.group(1): int(m.group(2))
            for m in _TOKEN_RE.finditer(metric)}


def _f32(*dims: int) -> float:
    total = 4.0
    for d in dims:
        total *= d
    return total


# benchmark-name prefix -> (cell label, flops(shape), bytes(shape))
# Shapes may be partial; model fns must tolerate missing tokens by
# raising KeyError (caught -> unmodeled fallback).
_MODELS: dict[str, tuple[str, Callable[[dict], float],
                         Callable[[dict], float]]] = {
    "kernel_pairwise_gram": (
        "pairwise_gram VPU L1 reduction (tile (TM,TN,TD) VMEM acc)",
        lambda s: 3.0 * s["G"] * s["G"] * s["D"],
        lambda s: _f32(2 * s["G"], s["D"]) + _f32(s["G"], s["G"]),
    ),
    "metrics_gram": (
        "pairwise_gram VPU L1 reduction (tile (TM,TN,TD) VMEM acc)",
        lambda s: 3.0 * s["G"] * s["G"] * s["D"],
        lambda s: _f32(2 * s["G"], s["D"]) + _f32(s["G"], s["G"]),
    ),
    "kernel_domination": (
        "domination closed-neighborhood subset check (tiled bool VPU)",
        lambda s: 2.0 * s["B"] * s["N"] ** 3,
        lambda s: 3.0 * _f32(s["B"], s["N"], s["N"]),
    ),
    "kernel_kcore": (
        "kcore peel degree sweep (jnp reduction)",
        lambda s: float(s["B"] * s["N"] ** 2),
        lambda s: _f32(s["B"], s["N"], s["N"]),
    ),
    "kernel_common_neighbors": (
        "common-neighbors A·A masked count (tiled int VPU)",
        lambda s: 2.0 * s["B"] * s["N"] ** 3,
        lambda s: 2.0 * _f32(s["B"], s["N"], s["N"]),
    ),
    "kernel_auction_lap": (
        "auction_lap bidding rounds (VMEM-resident (M,M) value matrix)",
        # ~3 row/col reductions per round, round count ~ 64 + 32·M
        lambda s: 3.0 * s["B"] * (64 + 32 * s["M"]) * s["M"] ** 2,
        lambda s: _f32(s["B"], s["M"], s["M"]),
    ),
    "kernel_sinkhorn_lse": (
        "sinkhorn_lse blocked online-LSE half-update (cost on the fly)",
        lambda s: 8.0 * s["B"] * s["M"] ** 2,
        lambda s: 6.0 * _f32(s["B"], s["M"]),
    ),
    "kernel_gf2_reduce": (
        "gf2_reduce packed GF(2) pivot chase (whole matrix in VMEM)",
        # worst-case column XOR chains: S^2 word ops over W = S/32 words
        lambda s: float(s["B"] * s["S"] ** 2 * max(s["S"] // 32, 1)),
        lambda s: _f32(s["B"], s["S"], max(s["S"] // 32, 1)),
    ),
    "metrics_blocked_sinkhorn": (
        "sinkhorn_lse blocked online-LSE vs dense cost materialization",
        lambda s: 8.0 * s["S"] ** 2,
        lambda s: 6.0 * _f32(s["S"]),
    ),
}

# benchmark-name prefix -> subsystem cell for unmodeled rows
_SUBSYSTEMS: tuple[tuple[str, str], ...] = (
    ("metrics_rerank", "TopoIndex two-stage retrieval (LSH coarse → "
                       "auction exact re-rank)"),
    ("metrics_serve_two_stage", "SimilarityServe drain (coarse top-k → "
                                "batched auction compare)"),
    ("metrics_drift", "TopoStream drift scoring through the metric "
                      "registry"),
    ("metrics_exact_w", "MetricEngine exact_w (auction-LAP on augmented "
                        "clouds)"),
    ("metrics_auction_parity", "MetricEngine exact_w (auction-LAP on "
                               "augmented clouds)"),
    ("metrics", "MetricEngine distance path (compare/pairwise)"),
    ("serve", "TopoServe drain (bucketed reduce→persist plan execution)"),
    ("stream", "TopoStream verdict + gathered recompute"),
    ("ego_decay", "ReductionEngine two-phase reduce→repack→persist"),
    ("coral_heavy", "ReductionEngine two-phase reduce→repack→persist"),
    ("reduction", "ReductionEngine two-phase reduce→repack→persist"),
    ("fig2", "persistence-kernel clustering (Gram + kernel kmeans)"),
    ("kernel", "Pallas kernel microbench"),
)


def attribute(suite: str, benchmark: str, metric: str) -> dict:
    """Cost cell for one regressed row.

    Returns ``{"cell", "bound", "modeled"}`` plus — for modeled kernels —
    the roofline terms (``compute_s``/``memory_s`` per-device estimates at
    the mesh's peak numbers, useful as a *ratio*, not a wall-clock
    prediction on CPU).
    """
    shape = parse_shape(metric)
    for prefix, (cell, flops_fn, bytes_fn) in _MODELS.items():
        if benchmark.startswith(prefix):
            try:
                flops, nbytes = flops_fn(shape), bytes_fn(shape)
            except KeyError:
                break  # metric name carries no shape tokens -> subsystem
            terms = roofline_terms(flops, nbytes, {})
            return {
                "cell": cell,
                "bound": terms["dominant"],
                "modeled": True,
                "flops": flops,
                "bytes": nbytes,
                "compute_s": terms["compute_s"],
                "memory_s": terms["memory_s"],
                "shape": shape,
            }
    for prefix, cell in _SUBSYSTEMS:
        if benchmark.startswith(prefix):
            return {"cell": cell, "bound": "unmodeled", "modeled": False,
                    "shape": shape}
    return {"cell": f"{suite}/{benchmark}", "bound": "unmodeled",
            "modeled": False, "shape": shape}
