"""TopoPipe: CoralTDA/PrunIT exact TDA reductions + multi-pod JAX LM stack."""
__version__ = "1.7.0"
