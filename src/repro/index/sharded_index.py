"""ShardedIndex: TopoIndex partitioned row-wise over a device mesh.

The single-host :class:`repro.index.topo_index.TopoIndex` caps the corpus
at one device's RAM and runs its coarse Hamming scan on the host.  This
module shards the whole retrieve path over a 2-D ``("row", "col")`` mesh
(:func:`repro.launch.mesh.make_index_mesh`) while keeping the TopoIndex
query surface — ``SimilarityServe`` and every other caller work unchanged:

* **row stores** — embeddings, packed LSH codes, and compacted clouds are
  partitioned in contiguous row blocks over the *flattened* mesh (shard
  ``p`` of ``P`` owns rows ``[p·per, (p+1)·per)``,
  ``launch.sharding.index_row_spec``);
* **coarse stage on-device** — a ``shard_map`` runs the Pallas
  XOR+popcount kernel (``repro.kernels.hamming``) over each shard's local
  codes, takes a per-shard top-``m`` (``lax.top_k``), and the host merges
  the ``P·m`` survivors.  The global top-``m`` is a subset of the union
  of per-shard top-``m``'s, and ties resolve by (distance, row) on both
  sides, so the merged candidate set is *identical* to the single-host
  scan's;
* **SUMMA distributed Gram** — for ``coarse="none"`` (and ``gram()``),
  pairwise L1 runs as a 2-D blocked SUMMA: corpus rows shard over
  ``"row"``, the embedding width over ``"col"``, and query blocks
  ring-stream along ``"row"`` via ``lax.ppermute`` — after step ``s``,
  mesh row ``r`` holds query block ``(r − s) mod R``, computes its local
  ``pairwise_l1`` block partial over the local width slice, and
  ``psum``'s over ``"col"``.  R steps cover every (query-block, row-group)
  pair with no all-gather of either operand;
* **shard-owner re-rank gather** — :meth:`clouds` groups requested rows
  by owning shard, gathers from that shard's cloud block, and scatters
  results back into request order (the serve-level exact re-rank path).

``add`` appends through the base index and marks the device state dirty;
the next query re-shards (append = re-shard, the simple policy at this
corpus scale).  ``save``/``load`` delegate to the TopoIndex ``.npz``
format — packed codes included since 1.7 — so sharded and single-host
indexes round-trip through the same files.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import obs
from repro.core.persistence_jax import Diagrams
from repro.index.topo_index import (
    QueryResult,
    TopoIndex,
    TopoIndexConfig,
    clouds_to_diagrams,
)
from repro.kernels import tuning
from repro.kernels.hamming import hamming_scan_pallas, pack_codes_u32
from repro.kernels.pairwise_gram import pairwise_l1_pallas
from repro.launch.mesh import make_index_mesh
from repro.launch.sharding import index_gram_specs, index_row_spec

# distance sentinel for padded rows inside the sharded scan: larger than
# any real Hamming count (lsh_bits <= 2^20) but far from int32 overflow
_PAD_DIST = np.int32(1) << 28

_C_SCANS = obs.counter(
    "index.sharded_scans",
    help="ShardedIndex device-side coarse scans / SUMMA gram calls")
_C_ROWS = obs.counter(
    "index.sharded_rows", help="corpus rows scanned across all shards")


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


class ShardedIndex:
    """Mesh-sharded retrieve→re-rank index with the TopoIndex surface.

    >>> index = ShardedIndex(TopoIndexConfig(coarse="lsh"))
    >>> index.add(diagrams, ids=["a", "b", "c"])
    >>> ids, dists = index.query(query_diagrams, k=2)

    Wrap an existing single-host index with :meth:`from_index`; the base
    index stays the host-side store of record (embeddings / ids / clouds),
    and this class owns the device-sharded replicas plus the distributed
    query plan.
    """

    def __init__(self, config: TopoIndexConfig | None = None, mesh=None,
                 base: TopoIndex | None = None):
        if base is not None and config is not None:
            raise ValueError("pass config or base, not both")
        self.base = base if base is not None else TopoIndex(config)
        self.mesh = mesh if mesh is not None else make_index_mesh()
        self._dirty = True
        self._codes_dev = None       # (P·per, W) u32, flattened-row sharded
        self._emb_dev = None         # (R·per_r, Dp) f32, ("row","col") sharded
        self._shard_clouds: list[np.ndarray] = []
        self._per = 0                # rows per shard (flattened partition)
        self._per_r = 0              # rows per mesh-row group (SUMMA)
        self._scan_fn = None
        self._summa_fn = None

    # --------------------------------------------------- TopoIndex surface

    @property
    def config(self) -> TopoIndexConfig:
        return self.base.config

    @property
    def ids(self) -> tuple[str, ...]:
        return self.base.ids

    def __len__(self) -> int:
        return len(self.base)

    @property
    def n_shards(self) -> int:
        return self.mesh.devices.size

    @classmethod
    def from_index(cls, index: TopoIndex, mesh=None) -> "ShardedIndex":
        return cls(mesh=mesh, base=index)

    def embed(self, d: Diagrams) -> jax.Array:
        return self.base.embed(d)

    def query_codes(self, d: Diagrams) -> np.ndarray:
        return self.base.query_codes(d)

    def add(self, d: Diagrams, ids: Optional[Sequence[str]] = None) -> list[str]:
        """Append through the base index; re-sharded lazily at next query."""
        out = self.base.add(d, ids=ids)
        self._dirty = True
        return out

    def save(self, path: str) -> None:
        self.base.save(path)

    @classmethod
    def load(cls, path: str, mesh=None) -> "ShardedIndex":
        """Load a TopoIndex save and shard it over ``mesh`` (lazily)."""
        return cls.from_index(TopoIndex.load(path), mesh=mesh)

    def clouds(self, rows: np.ndarray) -> Diagrams:
        """Shard-owner gather of stored clouds for ``rows`` (re-rank stage).

        Rows are grouped by owning shard (``row // per``), gathered from
        that shard's cloud block, and scattered back into request order —
        the distributed form of ``TopoIndex.clouds`` (same Diagrams
        layout, via the shared ``clouds_to_diagrams``).
        """
        if not self.base._has_clouds:
            # same contract as the base index: a pre-1.4 load keeps the
            # exact re-rank stage disabled rather than matching garbage
            return self.base.clouds(rows)
        self._ensure_device_state()
        rows = np.asarray(rows)
        flat = rows.reshape(-1).astype(np.int64)
        owner = flat // max(self._per, 1)
        local = flat - owner * self._per
        out = np.empty((flat.size, 3, self.config.n_points), np.float32)
        for p in np.unique(owner):
            sel = owner == p
            out[sel] = self._shard_clouds[int(p)][local[sel]]
        return clouds_to_diagrams(
            out.reshape(rows.shape + (3, self.config.n_points)),
            self.config.k)

    # ------------------------------------------------------- device state

    def _ensure_device_state(self) -> None:
        """(Re)build sharded device arrays + jitted plans after adds."""
        if not self._dirty:
            return
        base, mesh = self.base, self.mesh
        n = len(base)
        if n == 0:
            self._dirty = False
            return
        n_shards = mesh.devices.size
        rows_ax = mesh.shape["row"]
        cols_ax = mesh.shape["col"]
        per = -(-n // n_shards)
        per_r = -(-n // rows_ax)
        d = base._emb.shape[1]
        dp = -(-d // cols_ax) * cols_ax
        corpus_spec, query_spec, out_spec = index_gram_specs()

        # flattened row partition: packed codes (coarse scan) + cloud blocks
        if base.config.coarse == "lsh" and base._codes.size:
            codes = pack_codes_u32(base._codes)
            pad = np.zeros((n_shards * per - n, codes.shape[1]), np.uint32)
            self._codes_dev = jax.device_put(
                np.concatenate([codes, pad], axis=0),
                NamedSharding(mesh, index_row_spec()))
        else:
            self._codes_dev = None
        self._shard_clouds = [
            base._clouds[p * per:(p + 1) * per] for p in range(n_shards)]

        # SUMMA layout: rows over "row" groups, embedding width over "col"
        emb = np.zeros((rows_ax * per_r, dp), np.float32)
        emb[:n, :d] = base._emb
        self._emb_dev = jax.device_put(
            emb, NamedSharding(mesh, corpus_spec))

        self._per, self._per_r = per, per_r
        interp = _interpret()
        ht = tuning.resolve_tiles("hamming")
        gt = tuning.resolve_tiles("pairwise_gram")

        def scan(codes_all, q_codes, q_mask, *, m_loc: int):
            """Per-shard masked Hamming scan + local top-``m_loc``.

            Returns ``(dists, rows)`` shaped (P, Q, m_loc): per shard, the
            ``m_loc`` (distance, global-row) smallest local rows —
            ``lax.top_k`` on the negated distance prefers the lower local
            index on ties, matching the host merge's (dist, row) rule.
            """
            def body(codes_loc, qc, qm):
                dist = hamming_scan_pallas(
                    qc, qm, codes_loc, tile_q=ht["tile_q"],
                    tile_n=ht["tile_n"], interpret=interp)  # (Q, per) i32
                shard = (jax.lax.axis_index("row") * cols_ax
                         + jax.lax.axis_index("col"))
                gid = shard * per + jnp.arange(per, dtype=jnp.int32)
                dist = jnp.where(gid[None, :] < n, dist, _PAD_DIST)
                neg, loc = jax.lax.top_k(-dist, m_loc)
                return (-neg)[None], (shard * per + loc)[None]

            return shard_map(
                body, mesh=mesh,
                in_specs=(index_row_spec(), P(None, None), P(None, None)),
                out_specs=(P(("row", "col"), None, None),) * 2,
                check_rep=False,
            )(codes_all, q_codes, q_mask)

        def summa(q_blocks, corpus):
            """2-D blocked SUMMA pairwise-L1: (Qp, Dp) × (N', Dp) → (Qp, N').

            Query blocks ring-stream along "row" (``ppermute``); each step
            computes the local Gram block partial over this column's width
            slice and ``psum``'s over "col".  After step ``s`` mesh row
            ``r`` holds query block ``(r − s) mod R`` and writes its
            result into that output slot — R steps place every block.
            """
            def body(qb, db):
                r = jax.lax.axis_index("row")
                qb_rows = qb.shape[0]
                out0 = jnp.zeros((rows_ax, qb_rows, db.shape[0]),
                                 jnp.float32)

                def step(s, carry):
                    qb, out = carry
                    part = pairwise_l1_pallas(
                        qb, db, tile_m=gt["tile_m"], tile_n=gt["tile_n"],
                        tile_d=gt["tile_d"], interpret=interp)
                    part = jax.lax.psum(part, "col")
                    blk = jax.lax.rem(r - s + rows_ax, rows_ax)
                    out = jax.lax.dynamic_update_slice(
                        out, part[None], (blk, 0, 0))
                    qb = jax.lax.ppermute(
                        qb, "row",
                        [(i, (i + 1) % rows_ax) for i in range(rows_ax)])
                    return qb, out

                _, out = jax.lax.fori_loop(0, rows_ax, step, (qb, out0))
                return out.reshape(rows_ax * qb_rows, db.shape[0])

            return shard_map(
                body, mesh=mesh,
                in_specs=(query_spec, corpus_spec),
                out_specs=out_spec,
                check_rep=False,
            )(q_blocks, corpus)

        self._scan_fn = jax.jit(scan, static_argnames=("m_loc",))
        self._summa_fn = jax.jit(summa)
        self._dirty = False

    # -------------------------------------------------------------- query

    def _coarse_candidates(self, emb_q: np.ndarray, m: int,
                           probes: int | None = None) -> np.ndarray:
        """(Q, m) Hamming-nearest rows via the sharded on-device scan."""
        base = self.base
        margins = base._lsh_margins(emb_q)
        codes_q = pack_codes_u32(np.packbits(margins > 0, axis=-1))
        mask_u8 = base._query_bit_masks(margins, probes)
        mask_q = (np.full(codes_q.shape, 0xFFFFFFFF, np.uint32)
                  if mask_u8 is None else pack_codes_u32(mask_u8))
        n = len(base)
        m_loc = min(m, self._per)
        with obs.span("index.sharded_scan",
                      shape=f"Q{codes_q.shape[0]}_N{n}_P{self.n_shards}"):
            dd, rr = self._scan_fn(
                self._codes_dev, jnp.asarray(codes_q),
                jnp.asarray(mask_q), m_loc=m_loc)
        _C_SCANS.inc(kind="hamming")
        _C_ROWS.inc(n * codes_q.shape[0])
        # host-side merge of the per-shard top-m_loc survivors: same
        # composite dist·N + row key as TopoIndex._coarse_candidates, so
        # the merged set (ties included) is identical to the host scan's
        dd = np.asarray(dd).transpose(1, 0, 2).reshape(codes_q.shape[0], -1)
        rr = np.asarray(rr).transpose(1, 0, 2).reshape(codes_q.shape[0], -1)
        valid = dd < _PAD_DIST
        key = np.where(valid, dd.astype(np.int64) * n + rr, np.int64(2**62))
        key = np.take_along_axis(
            key, np.argpartition(key, m - 1, axis=-1)[:, :m], -1)
        key.sort(axis=-1)
        return key % n

    def query(self, d: Diagrams, k: int = 5,
              probes: int | None = None) -> QueryResult:
        """Batched kNN over the sharded corpus (TopoIndex semantics).

        ``coarse="lsh"``: sharded Hamming scan → host merge → one Gram
        call over the candidate union (``TopoIndex._rank_candidates``, so
        distances are bit-identical to the single-host index).
        ``coarse="none"`` / small corpus: full SUMMA distributed Gram.
        """
        base = self.base
        if not len(base):
            raise ValueError("query on an empty ShardedIndex")
        self._ensure_device_state()
        emb_q = base.embed(d)
        c = self.config
        n = len(base)
        kk = min(int(k), n)
        p = max(int(c.probes if probes is None else probes), 1)
        n_coarse = min(max(kk, 1) * c.lsh_overfetch * p, n)
        if c.coarse == "lsh" and n_coarse < n:
            cand = self._coarse_candidates(np.asarray(emb_q), n_coarse,
                                           probes=probes)
            dists, idx = base._rank_candidates(emb_q, cand, kk)
            stats = {"stage": "sharded_lsh+gram",
                     "coarse_candidates": int(n_coarse),
                     "probes": int(c.probes if probes is None else probes)}
        else:
            g = self._summa_gram(np.asarray(emb_q))
            rows = np.broadcast_to(np.arange(n, dtype=np.int64), g.shape)
            order = np.lexsort((rows, g), axis=-1)[:, :kk]
            dists = np.take_along_axis(g, order, axis=-1)
            idx = order
            stats = {"stage": "sharded_gram", "coarse_candidates": n}
        stats.update(shards=self.n_shards,
                     mesh={"row": int(self.mesh.shape["row"]),
                           "col": int(self.mesh.shape["col"])})
        ids = [[base._ids[j] for j in row] for row in idx]
        backends = [["gram"] * len(row) for row in idx]
        return QueryResult(ids, np.asarray(dists, np.float32), backends,
                           idx, stats)

    def _summa_gram(self, emb_q: np.ndarray) -> np.ndarray:
        """(Q, N) f32 L1 distances via the distributed SUMMA Gram."""
        self._ensure_device_state()
        mesh = self.mesh
        rows_ax = mesh.shape["row"]
        nq, d = emb_q.shape
        qp = -(-max(nq, 1) // rows_ax) * rows_ax
        dp = self._emb_dev.shape[1]
        q_pad = np.zeros((qp, dp), np.float32)
        q_pad[:nq, :d] = emb_q
        _, query_spec, _ = index_gram_specs()
        q_dev = jax.device_put(q_pad, NamedSharding(mesh, query_spec))
        with obs.span("index.sharded_gram",
                      shape=f"Q{nq}_N{len(self.base)}_P{self.n_shards}"):
            out = self._summa_fn(q_dev, self._emb_dev)
        _C_SCANS.inc(kind="summa")
        _C_ROWS.inc(len(self.base) * nq)
        # the flattened row partition pads only the last row group, so
        # device order == corpus order and the pad is one global tail slice
        return np.asarray(out)[:nq, :len(self.base)]

    def gram(self) -> np.ndarray:
        """(N, N) self-distance matrix via the distributed Gram."""
        self._ensure_device_state()
        return self._summa_gram(self.base._emb)
