"""TopoIndex: a similarity index over persistence-diagram embeddings.

The metrics layer turns diagrams into fixed-size vectors whose pairwise L1
distance is a diagram metric (``repro.metrics.sw_embedding``; optionally
concatenated with the ``repro.topo.features`` signature vector).  TopoIndex
stores those vectors host-side and answers batched k-nearest-neighbor
queries as a **retrieve → re-rank pipeline**:

* **coarse stage** (``coarse="lsh"``): packed hyperplane codes over the
  embeddings, Hamming-ranked with bit-count arithmetic — O(N·bits/64) per
  query, the only stage that touches all N rows, built for the >10⁶-graph
  regime;
* **Gram stage**: the tiled Pallas pairwise-L1 kernel
  (``repro.kernels.ops.pairwise_l1``) over the surviving candidates (or
  over the whole index when ``coarse="none"`` / the index is small) —
  the exact embedding metric, now demoted to stage one of serving;
* **exact stage** (serve-level): ``serve/similarity.py`` re-ranks the top
  Gram candidates with the auction-LAP ``exact_w`` backend, using the
  compacted top-persistence clouds this index stores per entry.

Every query answer is a :class:`QueryResult` that records, per returned
distance, which backend produced it (``"gram"`` embedding-L1 here;
``"exact_w"`` after the serve re-rank) — callers never silently mix
distance scales.

Embedding contract (docs/ARCHITECTURE.md §TopoIndex):

* the embedding width depends only on ``TopoIndexConfig`` (never on the
  diagram tensor size ``S``), so diagrams produced by different serve
  buckets / plans index into the same space;
* ``embed`` is pure and jit-backed — ``add`` and ``query`` accept the
  batched ``Diagrams`` layout directly;
* the LSH projection is a pure function of ``(width, lsh_bits, lsh_seed)``,
  so codes computed at different ``add`` calls (or after ``load``) are
  mutually consistent.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.persistence_jax import Diagrams
from repro.kernels import ops
from repro.metrics.distances import compact_top_k, sw_embedding
from repro.topo.features import feature_vector

EMBEDDINGS = ("sw", "features", "both")
COARSE = ("none", "lsh")

# byte → set-bit-count table: packed-code Hamming distances without the
# NumPy-2-only np.bitwise_count (the declared pin allows numpy >= 1.24)
_POPCOUNT = np.array([bin(i).count("1") for i in range(256)], np.uint8)


def clouds_to_diagrams(cl: np.ndarray, k: int) -> Diagrams:
    """Diagrams rebuilt from stored compacted clouds ``(..., 3, n_points)``.

    Shared by :meth:`TopoIndex.clouds` and the ShardedIndex shard-owner
    gather — one definition of the cloud → Diagrams layout.
    """
    keep = cl[..., 2, :] > 0
    return Diagrams(
        birth=jnp.asarray(cl[..., 0, :]),
        death=jnp.asarray(cl[..., 1, :]),
        dim=jnp.where(jnp.asarray(keep), k, -1),
        valid=jnp.asarray(keep))


@dataclasses.dataclass(frozen=True)
class TopoIndexConfig:
    """Embedding + query policy (fully determines the embedding space)."""

    embedding: str = "sw"      # "sw" | "features" | "both"
    k: int = 1                 # homology dimension of the sw embedding
    n_points: int = 16         # top-persistence points kept per diagram
    n_dirs: int = 16           # SW direction-grid resolution
    cap: float = 64.0          # essential-class death cap
    res: int = 8               # persistence-image resolution (features)
    max_dim: int = 1           # feature dims 0..max_dim (features)
    feature_weight: float = 1.0  # scale of the features block ("both")
    coarse: str = "none"       # "none" | "lsh": Hamming prefilter stage
    lsh_bits: int = 128        # hyperplane code width (multiple of 8)
    lsh_seed: int = 7          # projection seed (defines the code space)
    lsh_overfetch: int = 8     # coarse candidates per query = k · overfetch
    probes: int = 1            # multi-probe LSH budget (1 = single probe)

    def __post_init__(self):
        if self.embedding not in EMBEDDINGS:
            raise ValueError(
                f"unknown embedding {self.embedding!r}; want one of "
                f"{EMBEDDINGS}")
        if self.coarse not in COARSE:
            raise ValueError(
                f"unknown coarse stage {self.coarse!r}; want one of {COARSE}")
        if self.lsh_bits % 8 or self.lsh_bits <= 0:
            raise ValueError(
                f"lsh_bits must be a positive multiple of 8, "
                f"got {self.lsh_bits}")
        if self.probes < 1:
            raise ValueError(f"probes must be >= 1, got {self.probes}")
        if (self.probes - 1).bit_length() >= self.lsh_bits:
            raise ValueError(
                f"probes={self.probes} would mask {self.flip_bits} of "
                f"{self.lsh_bits} code bits — the coarse stage would stop "
                "discriminating")

    @property
    def flip_bits(self) -> int:
        """Low-margin query bits masked per query: smallest t with 2^t >= probes.

        Masking the t least-confident bits of a query code out of the
        Hamming distance equals taking the min over all 2^t flip-probe
        codes — so a ``probes`` budget costs one masked scan, not
        ``probes`` scans.
        """
        return (self.probes - 1).bit_length()

    @property
    def width(self) -> int:
        """Embedding width — fixed by the config, independent of S."""
        w = 0
        if self.embedding in ("sw", "both"):
            w += self.n_dirs * 2 * self.n_points
        if self.embedding in ("features", "both"):
            w += (6 + self.res * self.res) * (self.max_dim + 1)
        return w


class QueryResult:
    """One batched kNN answer with per-distance backend provenance.

    ``ids``: (B, k') nested id lists, nearest first; ``distances``:
    (B, k') float32; ``backends``: (B, k') nested lists naming the backend
    each distance came from (``"gram"`` = embedding-L1; the serve re-rank
    substitutes ``"exact_w"``); ``rows``: (B, k') int index rows of the
    returned entries (what the serve re-rank gathers stored clouds by);
    ``stats``: per-stage query statistics (``stage``,
    ``coarse_candidates``).

    Iterates and indexes like the legacy ``(ids, distances)`` tuple, so
    ``ids, dists = index.query(...)`` keeps working.
    """

    __slots__ = ("ids", "distances", "backends", "rows", "stats")

    def __init__(self, ids, distances, backends, rows, stats):
        self.ids = ids
        self.distances = distances
        self.backends = backends
        self.rows = rows
        self.stats = stats

    def __iter__(self):
        return iter((self.ids, self.distances))

    def __getitem__(self, i):
        # exactly the legacy 2-tuple surface (negative indices included);
        # backends/stats are attribute-only so no old call site silently
        # picks up a different element
        return (self.ids, self.distances)[i]

    def __len__(self):
        return 2

    def __repr__(self):
        b = len(self.ids)
        k = len(self.ids[0]) if self.ids else 0
        return (f"QueryResult(B={b}, k={k}, stage={self.stats.get('stage')!r}"
                f", coarse_candidates={self.stats.get('coarse_candidates')})")


class TopoIndex:
    """Retrieve→re-rank kNN index over diagram embeddings.

    >>> index = TopoIndex()
    >>> index.add(diagrams, ids=["a", "b", "c"])
    >>> ids, dists = index.query(query_diagrams, k=2)
    """

    def __init__(self, config: TopoIndexConfig | None = None):
        self.config = config or TopoIndexConfig()
        self._emb = np.zeros((0, self.config.width), np.float32)
        self._ids: list[str] = []
        # compacted top-persistence clouds (N, 3, n_points): birth, death,
        # keep — what the serve-level exact_w re-rank matches against
        self._clouds = np.zeros((0, 3, self.config.n_points), np.float32)
        self._has_clouds = True  # False only for pre-1.4 loads
        # packed LSH codes (N, lsh_bits/8) u8, maintained when coarse="lsh"
        self._codes = np.zeros((0, self.config.lsh_bits // 8), np.uint8)
        self._proj: Optional[np.ndarray] = None
        # device-resident copy of _emb, built lazily and invalidated by add()
        # so steady-state queries skip the O(N·D) host-to-device re-upload
        self._emb_device: Optional[jax.Array] = None

    def __len__(self) -> int:
        return len(self._ids)

    @property
    def ids(self) -> tuple[str, ...]:
        return tuple(self._ids)

    # ---------------------------------------------------------- embedding

    def embed(self, d: Diagrams) -> jax.Array:
        """(B, width) embedding of a batched Diagrams tensor."""
        c = self.config
        parts = []
        if c.embedding in ("sw", "both"):
            parts.append(sw_embedding(d, k=c.k, n_points=c.n_points,
                                      n_dirs=c.n_dirs, cap=c.cap))
        if c.embedding in ("features", "both"):
            fv = feature_vector(d, max_dim=c.max_dim, res=c.res, cap=c.cap)
            parts.append(c.feature_weight * fv)
        emb = jnp.concatenate(parts, axis=-1)
        if emb.ndim == 1:
            emb = emb[None]
        return emb.astype(jnp.float32)

    def _projection(self) -> np.ndarray:
        """(width, lsh_bits) hyperplane normals — pure in (width, bits, seed)."""
        if self._proj is None:
            rng = np.random.default_rng(self.config.lsh_seed)
            self._proj = rng.standard_normal(
                (self.config.width, self.config.lsh_bits)).astype(np.float32)
        return self._proj

    def _lsh_margins(self, emb: np.ndarray) -> np.ndarray:
        """(B, lsh_bits) signed hyperplane margins of (B, width) embeddings.

        Embeddings are row-centered first: SW embeddings share a large
        positive common component (sorted nonnegative projections), and
        hyperplane signs only discriminate after that shared direction is
        projected out.  ``margin > 0`` is the code bit; ``|margin|`` is
        the bit's confidence (what multi-probe masks by).
        """
        centered = emb - emb.mean(axis=-1, keepdims=True)
        return centered @ self._projection()

    def _lsh_codes(self, emb: np.ndarray) -> np.ndarray:
        """(B, lsh_bits/8) packed hyperplane codes of (B, width) embeddings."""
        return np.packbits(self._lsh_margins(emb) > 0, axis=-1)

    def _query_bit_masks(self, margins: np.ndarray,
                         probes: int | None = None) -> Optional[np.ndarray]:
        """(B, lsh_bits/8) packed query masks for multi-probe, or ``None``.

        Clears the ``flip_bits`` lowest-``|margin|`` bits per query —
        equivalent to the min over all ``2^flip_bits`` flip-probe codes
        (see :attr:`TopoIndexConfig.flip_bits`).  ``None`` when the probe
        budget is 1 (plain Hamming, no mask needed).
        """
        p = self.config.probes if probes is None else int(probes)
        if p < 1:
            raise ValueError(f"probes must be >= 1, got {p}")
        t = (p - 1).bit_length()
        if t == 0:
            return None
        if t >= self.config.lsh_bits:
            raise ValueError(
                f"probes={p} would mask {t} of {self.config.lsh_bits} bits")
        keep = np.ones(margins.shape, bool)
        flip = np.argpartition(np.abs(margins), t - 1, axis=-1)[:, :t]
        np.put_along_axis(keep, flip, False, axis=-1)
        return np.packbits(keep, axis=-1)

    def query_codes(self, d: Diagrams) -> np.ndarray:
        """(B, lsh_bits/8) packed LSH bucket codes of a query batch.

        Pure in ``(config, d)`` and computed regardless of the ``coarse``
        setting — the serve-level auction price cache keys warm-start
        vectors by these codes even when the coarse Hamming stage is off
        (``repro.metrics.price_cache``).
        """
        return self._lsh_codes(np.asarray(self.embed(d)))

    # -------------------------------------------------------- add / query

    def add(self, d: Diagrams, ids: Optional[Sequence[str]] = None) -> list[str]:
        """Embed and append a batch; returns the assigned ids."""
        emb = np.asarray(self.embed(d))
        if ids is None:
            ids = [f"g{len(self._ids) + i}" for i in range(emb.shape[0])]
        ids = [str(i) for i in ids]
        if len(ids) != emb.shape[0]:
            raise ValueError(f"{len(ids)} ids for {emb.shape[0]} diagrams")
        dup = set(ids) & set(self._ids)
        if dup:
            raise ValueError(f"duplicate ids: {sorted(dup)}")
        c = self.config
        b, e, keep = compact_top_k(d, c.k, c.n_points, c.cap)
        clouds = np.stack([np.asarray(b, np.float32),
                           np.asarray(e, np.float32),
                           np.asarray(keep, np.float32)], axis=-2)
        clouds = clouds.reshape(-1, 3, c.n_points)
        self._emb = np.concatenate([self._emb, emb], axis=0)
        self._clouds = np.concatenate([self._clouds, clouds], axis=0)
        if c.coarse == "lsh":
            self._codes = np.concatenate(
                [self._codes, self._lsh_codes(emb)], axis=0)
        self._ids.extend(ids)
        self._emb_device = None
        return ids

    def _device_emb(self) -> jax.Array:
        if self._emb_device is None:
            self._emb_device = jnp.asarray(self._emb)
        return self._emb_device

    def clouds(self, rows: np.ndarray) -> Diagrams:
        """Diagrams rebuilt from the stored compacted clouds of ``rows``.

        Leaves are shaped ``rows.shape + (n_points,)`` — the fixed-width
        dim-``k`` sub-diagrams the exact re-rank backend matches against
        (deaths already capped at ``config.cap``).
        """
        if not self._has_clouds:
            raise ValueError(
                "index was loaded from a save without stored clouds "
                "(pre-1.4 format); re-add the diagrams to enable the "
                "exact re-rank stage")
        return clouds_to_diagrams(self._clouds[rows], self.config.k)

    def _coarse_candidates(self, emb_q: np.ndarray, m: int,
                           probes: int | None = None,
                           chunk: int = 1 << 16) -> np.ndarray:
        """(Q, m) Hamming-nearest row indices (coarse LSH stage).

        XOR + popcount over the packed axis, streamed in ``chunk``-row
        blocks with a running per-query top-``m`` merge — peak memory is
        O(Q·(chunk·bits/8 + m)), never the full (Q, N) distance matrix,
        so the host fallback stays bounded at 10⁷ entries.  With a
        ``probes`` budget > 1 the ``flip_bits`` lowest-margin query bits
        are masked out of the distance (one masked scan == min over all
        flip-probe codes).  Ties break toward the lower row index, so the
        result is deterministic and independent of chunking — the same
        rule the sharded per-shard top-m merge uses.
        """
        margins = self._lsh_margins(emb_q)
        codes_q = np.packbits(margins > 0, axis=-1)
        mask_q = self._query_bit_masks(margins, probes)
        n = self._codes.shape[0]
        nq = codes_q.shape[0]
        # running top-m on the composite key dist·N + row: boundary ties
        # resolve to the lower row index *exactly*, whatever the chunking
        best = np.zeros((nq, 0), np.int64)
        for s in range(0, n, chunk):
            x = codes_q[:, None, :] ^ self._codes[None, s:s + chunk, :]
            if mask_q is not None:
                x &= mask_q[:, None, :]
            d = _POPCOUNT[x].sum(axis=-1, dtype=np.int64)
            key = d * n + np.arange(s, s + d.shape[1], dtype=np.int64)
            cat = np.concatenate([best, key], axis=1)
            if cat.shape[1] > m:
                cat = np.take_along_axis(
                    cat, np.argpartition(cat, m - 1, axis=-1)[:, :m], -1)
            best = cat
        best.sort(axis=-1)
        return best % n

    def _rank_candidates(self, emb_q, cand: np.ndarray,
                         kk: int) -> tuple[np.ndarray, np.ndarray]:
        """Gram-rank (Q, m) candidate rows → top-``kk`` (dists, rows).

        One Pallas L1 Gram call over the candidate union; shared with the
        ShardedIndex re-rank (its host-merged coarse candidates land here
        too, so both index flavors rank with bit-identical arithmetic).
        """
        union, inv = np.unique(cand, return_inverse=True)
        inv = inv.reshape(cand.shape)
        gram_u = np.asarray(ops.pairwise_l1(
            emb_q, jnp.asarray(self._emb[union])))
        # per query: distances to its own candidates only
        q_idx = np.arange(cand.shape[0])[:, None]
        cand_d = gram_u[q_idx, inv]                       # (Q, m)
        order = np.argsort(cand_d, axis=-1, kind="stable")[:, :kk]
        dists = np.take_along_axis(cand_d, order, axis=-1)
        idx = np.take_along_axis(cand, order, axis=-1)
        return dists, idx

    def query(self, d: Diagrams, k: int = 5,
              probes: int | None = None) -> QueryResult:
        """Batched kNN: nearest first, with per-distance backend labels.

        ``coarse="none"`` (or a small index): one (Q, N) Pallas Gram call.
        ``coarse="lsh"``: Hamming top ``k·lsh_overfetch·probes`` per
        query, then the Gram kernel over the candidate union — distances
        returned are always the embedding-L1 metric (backend ``"gram"``),
        never raw Hamming counts.  ``probes`` overrides the config's
        multi-probe budget for this query batch: as in bucketed
        multi-probe LSH, a ``probes`` budget examines ``probes``× the
        candidates (one bucket's worth each), and the margin-masked scan
        (min over all flip-probe codes) admits exactly the rows those
        probed buckets would — still in one pass over the codes.
        """
        if not self._ids:
            raise ValueError("query on an empty TopoIndex")
        emb_q = self.embed(d)
        c = self.config
        kk = min(int(k), len(self._ids))
        p = max(int(c.probes if probes is None else probes), 1)
        n_coarse = min(max(kk, 1) * c.lsh_overfetch * p, len(self._ids))
        if c.coarse == "lsh" and n_coarse < len(self._ids):
            cand = self._coarse_candidates(np.asarray(emb_q), n_coarse,
                                           probes=probes)
            dists, idx = self._rank_candidates(emb_q, cand, kk)
            stats = {"stage": "lsh+gram", "coarse_candidates": int(n_coarse),
                     "probes": int(c.probes if probes is None else probes)}
        else:
            gram = ops.pairwise_l1(emb_q, self._device_emb())
            neg, idx = jax.lax.top_k(-gram, kk)
            dists = np.asarray(-neg, np.float32)
            idx = np.asarray(idx)
            stats = {"stage": "gram", "coarse_candidates": len(self._ids)}
        ids = [[self._ids[j] for j in row] for row in idx]
        backends = [["gram"] * len(row) for row in idx]
        return QueryResult(ids, np.asarray(dists, np.float32), backends,
                           idx, stats)

    def gram(self) -> np.ndarray:
        """(N, N) self-distance matrix of the whole index (clustering input)."""
        e = self._device_emb()
        return np.asarray(ops.pairwise_l1(e, e))

    # -------------------------------------------------------- persistence

    def save(self, path: str) -> None:
        """Write embeddings + clouds + ids + config as one ``.npz``.

        Writes to ``path`` verbatim (via a file handle — ``np.savez`` on a
        bare path would append ``.npz`` and break the save/load round-trip).
        Packed LSH codes are stored when the coarse stage is on, so a load
        (and the ShardedIndex re-shard after it) skips the O(N·bits)
        code rebuild; they stay a pure function of config + embeddings, so
        pre-codes saves simply rebuild on load.  An index loaded from a
        pre-clouds save re-saves *without* a clouds array (its placeholder
        is all-zero), so a later load keeps the re-rank stage disabled
        instead of silently matching against garbage.
        """
        payload = dict(
            emb=self._emb,
            ids=np.asarray(self._ids, dtype=np.str_),
            config=np.str_(json.dumps(dataclasses.asdict(self.config))),
        )
        if self._has_clouds:
            payload["clouds"] = self._clouds
        if self.config.coarse == "lsh":
            payload["codes"] = self._codes
        with open(path, "wb") as fh:
            np.savez(fh, **payload)

    @classmethod
    def load(cls, path: str) -> "TopoIndex":
        with np.load(path, allow_pickle=False) as z:
            config = TopoIndexConfig(**json.loads(str(z["config"])))
            index = cls(config)
            emb = np.asarray(z["emb"], np.float32)
            if emb.shape[1] != config.width:
                raise ValueError(
                    f"embedding width {emb.shape[1]} does not match config "
                    f"width {config.width}")
            index._emb = emb
            index._ids = [str(i) for i in z["ids"]]
            if "clouds" in z.files:
                index._clouds = np.asarray(z["clouds"], np.float32)
            else:  # pre-1.4 save: queryable, but no exact re-rank stage
                index._clouds = np.zeros(
                    (len(index._ids), 3, config.n_points), np.float32)
                index._has_clouds = False
            if config.coarse == "lsh":
                codes = (np.asarray(z["codes"], np.uint8)
                         if "codes" in z.files else None)
                if codes is not None and codes.shape == (
                        emb.shape[0], config.lsh_bits // 8):
                    index._codes = codes
                else:  # pre-1.7 save (or width drift): rebuild from emb
                    index._codes = index._lsh_codes(emb)
        return index
