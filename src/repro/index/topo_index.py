"""TopoIndex: a similarity index over persistence-diagram embeddings.

The metrics layer turns diagrams into fixed-size vectors whose pairwise L1
distance is a diagram metric (``repro.metrics.sw_embedding``; optionally
concatenated with the ``repro.topo.features`` signature vector).  TopoIndex
stores those vectors host-side and answers batched k-nearest-neighbor
queries by running the tiled Pallas Gram kernel
(``repro.kernels.ops.pairwise_l1``) between the query embeddings and the
index, then ``top_k`` over the negated distances — the "which known graphs
look like this one" serving primitive (Aktas et al. §applications).

Embedding contract (docs/ARCHITECTURE.md §TopoIndex):

* the embedding width depends only on ``TopoIndexConfig`` (never on the
  diagram tensor size ``S``), so diagrams produced by different serve
  buckets / plans index into the same space;
* ``embed`` is pure and jit-backed — ``add`` and ``query`` accept the
  batched ``Diagrams`` layout directly;
* distances returned by ``query`` are exactly the metric the Gram kernel
  computes (L1 between embeddings; for the ``"sw"`` embedding that is the
  anchored sliced-Wasserstein approximation of ``repro.metrics``).

The index is deliberately exact and dense (a (Q, N) Gram per query batch);
an ANN structure for >10⁶ graphs is a ROADMAP item.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.persistence_jax import Diagrams
from repro.kernels import ops
from repro.metrics.distances import sw_embedding
from repro.topo.features import feature_vector

EMBEDDINGS = ("sw", "features", "both")


@dataclasses.dataclass(frozen=True)
class TopoIndexConfig:
    """Embedding + query policy (fully determines the embedding space)."""

    embedding: str = "sw"      # "sw" | "features" | "both"
    k: int = 1                 # homology dimension of the sw embedding
    n_points: int = 16         # top-persistence points kept per diagram
    n_dirs: int = 16           # SW direction-grid resolution
    cap: float = 64.0          # essential-class death cap
    res: int = 8               # persistence-image resolution (features)
    max_dim: int = 1           # feature dims 0..max_dim (features)
    feature_weight: float = 1.0  # scale of the features block ("both")

    def __post_init__(self):
        if self.embedding not in EMBEDDINGS:
            raise ValueError(
                f"unknown embedding {self.embedding!r}; want one of "
                f"{EMBEDDINGS}")

    @property
    def width(self) -> int:
        """Embedding width — fixed by the config, independent of S."""
        w = 0
        if self.embedding in ("sw", "both"):
            w += self.n_dirs * 2 * self.n_points
        if self.embedding in ("features", "both"):
            w += (6 + self.res * self.res) * (self.max_dim + 1)
        return w


class TopoIndex:
    """Exact kNN index over diagram embeddings.

    >>> index = TopoIndex()
    >>> index.add(diagrams, ids=["a", "b", "c"])
    >>> ids, dists = index.query(query_diagrams, k=2)
    """

    def __init__(self, config: TopoIndexConfig | None = None):
        self.config = config or TopoIndexConfig()
        self._emb = np.zeros((0, self.config.width), np.float32)
        self._ids: list[str] = []
        # device-resident copy of _emb, built lazily and invalidated by add()
        # so steady-state queries skip the O(N·D) host-to-device re-upload
        self._emb_device: Optional[jax.Array] = None

    def __len__(self) -> int:
        return len(self._ids)

    @property
    def ids(self) -> tuple[str, ...]:
        return tuple(self._ids)

    # ---------------------------------------------------------- embedding

    def embed(self, d: Diagrams) -> jax.Array:
        """(B, width) embedding of a batched Diagrams tensor."""
        c = self.config
        parts = []
        if c.embedding in ("sw", "both"):
            parts.append(sw_embedding(d, k=c.k, n_points=c.n_points,
                                      n_dirs=c.n_dirs, cap=c.cap))
        if c.embedding in ("features", "both"):
            fv = feature_vector(d, max_dim=c.max_dim, res=c.res, cap=c.cap)
            parts.append(c.feature_weight * fv)
        emb = jnp.concatenate(parts, axis=-1)
        if emb.ndim == 1:
            emb = emb[None]
        return emb.astype(jnp.float32)

    # -------------------------------------------------------- add / query

    def add(self, d: Diagrams, ids: Optional[Sequence[str]] = None) -> list[str]:
        """Embed and append a batch; returns the assigned ids."""
        emb = np.asarray(self.embed(d))
        if ids is None:
            ids = [f"g{len(self._ids) + i}" for i in range(emb.shape[0])]
        ids = [str(i) for i in ids]
        if len(ids) != emb.shape[0]:
            raise ValueError(f"{len(ids)} ids for {emb.shape[0]} diagrams")
        dup = set(ids) & set(self._ids)
        if dup:
            raise ValueError(f"duplicate ids: {sorted(dup)}")
        self._emb = np.concatenate([self._emb, emb], axis=0)
        self._ids.extend(ids)
        self._emb_device = None
        return ids

    def _device_emb(self) -> jax.Array:
        if self._emb_device is None:
            self._emb_device = jnp.asarray(self._emb)
        return self._emb_device

    def query(self, d: Diagrams, k: int = 5) -> tuple[list[list[str]], np.ndarray]:
        """Batched kNN: returns ``(ids, distances)``, nearest first.

        ``ids`` is a (B, k') nested list and ``distances`` a (B, k') float32
        array with ``k' = min(k, len(index))``.  The (Q, N) distance matrix
        is one Pallas Gram call (``kernels/pairwise_gram.py``).
        """
        if not self._ids:
            raise ValueError("query on an empty TopoIndex")
        emb_q = self.embed(d)
        gram = ops.pairwise_l1(emb_q, self._device_emb())
        kk = min(int(k), len(self._ids))
        neg, idx = jax.lax.top_k(-gram, kk)
        dists = np.asarray(-neg, np.float32)
        idx = np.asarray(idx)
        ids = [[self._ids[j] for j in row] for row in idx]
        return ids, dists

    def gram(self) -> np.ndarray:
        """(N, N) self-distance matrix of the whole index (clustering input)."""
        e = self._device_emb()
        return np.asarray(ops.pairwise_l1(e, e))

    # -------------------------------------------------------- persistence

    def save(self, path: str) -> None:
        """Write embeddings + ids + config as one ``.npz``.

        Writes to ``path`` verbatim (via a file handle — ``np.savez`` on a
        bare path would append ``.npz`` and break the save/load round-trip).
        """
        with open(path, "wb") as fh:
            np.savez(
                fh,
                emb=self._emb,
                ids=np.asarray(self._ids, dtype=np.str_),
                config=np.str_(json.dumps(dataclasses.asdict(self.config))),
            )

    @classmethod
    def load(cls, path: str) -> "TopoIndex":
        with np.load(path, allow_pickle=False) as z:
            config = TopoIndexConfig(**json.loads(str(z["config"])))
            index = cls(config)
            emb = np.asarray(z["emb"], np.float32)
            if emb.shape[1] != config.width:
                raise ValueError(
                    f"embedding width {emb.shape[1]} does not match config "
                    f"width {config.width}")
            index._emb = emb
            index._ids = [str(i) for i in z["ids"]]
        return index
