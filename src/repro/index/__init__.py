"""TopoIndex: retrieve→re-rank persistence-diagram similarity index over
SW/feature embeddings (docs/ARCHITECTURE.md §TopoIndex)."""
from repro.index.topo_index import QueryResult, TopoIndex, TopoIndexConfig

__all__ = ["QueryResult", "TopoIndex", "TopoIndexConfig"]
