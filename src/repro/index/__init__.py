"""TopoIndex: persistence-diagram similarity index over SW/feature
embeddings (docs/ARCHITECTURE.md §TopoIndex)."""
from repro.index.topo_index import TopoIndex, TopoIndexConfig

__all__ = ["TopoIndex", "TopoIndexConfig"]
