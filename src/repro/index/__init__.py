"""TopoIndex: retrieve→re-rank persistence-diagram similarity index over
SW/feature embeddings, plus its mesh-sharded flavor
(docs/ARCHITECTURE.md §TopoIndex / §ShardedIndex)."""
from repro.index.sharded_index import ShardedIndex
from repro.index.topo_index import QueryResult, TopoIndex, TopoIndexConfig

__all__ = ["QueryResult", "ShardedIndex", "TopoIndex", "TopoIndexConfig"]
