"""Batched serving example: prefill a batch of prompts, decode new tokens
with a KV cache, across three different architecture families (dense GQA,
MoE, RWKV6) through the same serve API.

  PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import reduced_config
from repro.data.tokens import TokenStream
from repro.models import transformer as tf
from repro.serve.serve_step import generate


def main():
    for arch in ("qwen3-1.7b", "olmoe-1b-7b", "rwkv6-1.6b"):
        cfg = reduced_config(arch)
        params = tf.init_params(cfg, jax.random.PRNGKey(0))
        stream = TokenStream(vocab_size=cfg.vocab_size, batch=4, seq_len=12)
        prompts = stream.batch_at(jnp.int32(0))["tokens"]
        t0 = time.time()
        out = generate(params, cfg, prompts, max_new=16, s_kv=48,
                       temperature=0.8, rng=jax.random.PRNGKey(1))
        jax.block_until_ready(out)
        dt = time.time() - t0
        new = np.asarray(out)[:, prompts.shape[1]:]
        print(f"{arch:14s} [{cfg.family}] batch=4, 16 new tokens in {dt:5.1f}s"
              f" -> sample: {new[0][:8].tolist()}")


if __name__ == "__main__":
    main()
