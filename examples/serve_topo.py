"""TopoServe demo: a synthetic ego-net query stream served in padded buckets.

Simulates the paper's §6.2 regime as online traffic — clients keep asking
"what is the persistence diagram of THIS vertex's ego net?" — and shows the
TopoServe scheduler batching those single-graph queries into a bounded set
of jit signatures while a background thread drains the queues.

  PYTHONPATH=src python examples/serve_topo.py
"""
import threading
import time

import networkx as nx
import numpy as np

from repro.core.api import plan_cache_info
from repro.serve import TopoServe, TopoServeConfig


def synthetic_ego_queries(n_queries: int, seed: int = 0):
    """Ego nets of a preferential-attachment host graph, as (edges, n, f)."""
    host = nx.barabasi_albert_graph(400, 3, seed=seed)
    deg = dict(host.degree())
    rng = np.random.default_rng(seed)
    centers = rng.integers(0, host.number_of_nodes(), size=n_queries)
    for c in centers:
        ego = nx.ego_graph(host, int(c), radius=1)
        nodes = sorted(ego.nodes())
        if len(nodes) > 64:  # stay inside the default bucket ladder
            nodes = sorted(nodes, key=lambda u: deg[u], reverse=True)[:64]
            ego = host.subgraph(nodes)
            nodes = sorted(ego.nodes())
        idx = {u: i for i, u in enumerate(nodes)}
        edges = [(idx[u], idx[v]) for (u, v) in ego.edges()]
        # paper Remark 1: filtration values come from the HOST graph
        f = [float(deg[u]) for u in nodes]
        yield edges, len(nodes), f


def main():
    # pad_batch_to bounds the set of executed batch shapes (-> bounded jit
    # recompiles) even though the drain thread races the submission loop
    server = TopoServe(TopoServeConfig(dim=1, method="prunit",
                                       sublevel=False, max_batch=64,
                                       pad_batch_to=64))
    drainer = threading.Thread(target=server.serve_forever, daemon=True)
    drainer.start()

    futures = []
    t0 = time.perf_counter()
    for edges, n, f in synthetic_ego_queries(200, seed=7):
        futures.append(server.submit(edges=edges, n_vertices=n, f=f))
    results = [fut.result(timeout=120) for fut in futures]
    wall = time.perf_counter() - t0
    server.stop()

    # 1-hop ego nets are cones (H1 of the clique complex is trivial), so the
    # per-vertex signal lives in PD0: how neighborhood components merge as
    # the degree filtration sweeps (the TRL feature of the paper's §6.2)
    h0 = np.array([int(d.count(0)) for d in results])
    lat = np.array([f.latency_s() for f in futures]) * 1e3
    print(f"served {len(results)} ego-net queries in {wall:.2f}s "
          f"({len(results)/wall:.1f} graphs/s)")
    print(f"latency p50/p99: {np.percentile(lat, 50):.1f} / "
          f"{np.percentile(lat, 99):.1f} ms")
    print(f"PD0 features per query: mean {h0.mean():.2f}, max {h0.max()}")
    per_bucket = {f"n{b.n_pad}": s["served"]
                  for b, s in server.stats["per_bucket"].items() if s["served"]}
    print("graphs per bucket:", per_bucket)
    print("plan cache:", plan_cache_info())


if __name__ == "__main__":
    main()
