"""TopoScope demo: tracing + metrics over the serving stack under load.

Runs the two serving frontends with tracing enabled — a repack="on"
TopoServe batch of synthetic ego-net queries, then a TopoStream session
replayed through StreamServe — and shows the three TopoScope outputs:

* ``results/obs/trace_serve.json`` — Chrome-trace JSON of every span
  (``serve.drain`` → ``serve.batch`` → ``plan.reduce/…/persist``),
  loadable in Perfetto (https://ui.perfetto.dev);
* ``results/obs/metrics_serve.prom`` — Prometheus text snapshot of the
  metrics registry (counters/gauges/histograms the ``stats`` surfaces
  are views over);
* the self-time report (``python -m repro.obs report``) with kernel
  spans attributed to PerfGate's roofline cost cells.

  PYTHONPATH=src python examples/observability.py
"""
import jax
import networkx as nx
import numpy as np

from repro import obs
from repro.core.delta import delta_step
from repro.data.temporal import ego_decay_stream
from repro.obs.report import format_report
from repro.serve import StreamServe, TopoServe, TopoServeConfig
from repro.stream import TopoStreamConfig

TRACE_PATH = "results/obs/trace_serve.json"
PROM_PATH = "results/obs/metrics_serve.prom"


def ego_queries(n_queries: int, seed: int = 0):
    """(edges, n_vertices) ego nets of a preferential-attachment host."""
    host = nx.barabasi_albert_graph(300, 3, seed=seed)
    rng = np.random.default_rng(seed)
    for c in rng.integers(0, host.number_of_nodes(), size=n_queries):
        ego = nx.ego_graph(host, int(c), radius=1)
        nodes = sorted(ego.nodes())[:64]  # stay inside the bucket ladder
        ego = host.subgraph(nodes)
        idx = {u: i for i, u in enumerate(nodes)}
        yield [(idx[u], idx[v]) for (u, v) in ego.edges()], len(nodes)


def main():
    # tracing is off by default; one call (or REPRO_OBS=1) turns it on.
    # Metrics are always live — this only starts span recording.
    obs.configure(enabled=True)

    # ---- TopoServe: batched queries through the two-phase repack plan ---
    server = TopoServe(TopoServeConfig(dim=1, method="prunit",
                                       sublevel=False, repack="on"))
    futs = [server.submit(edges=e, n_vertices=n)
            for e, n in ego_queries(120, seed=7)]
    server.drain()
    for f in futs:
        f.result()
    print(f"TopoServe: {server.stats['served']} served in "
          f"{server.stats['batches']} batches "
          f"(repack rungs: {sorted(server.stats['repack_rungs'])})")

    # ---- StreamServe: a dynamic-network session on top ------------------
    key = jax.random.PRNGKey(42)
    g0, deltas = ego_decay_stream(key, batch=8, n_pad=32, n_core=10,
                                  n_double=6, n_pendant=6, steps=30,
                                  toggles=1, p_core_edge=0.15)
    streamer = StreamServe(TopoStreamConfig(dim=1, method="both",
                                            edge_cap=192, tri_cap=512))
    sid = streamer.create_session(g0)
    sfuts = [streamer.submit(sid, delta_step(deltas, t)) for t in range(30)]
    streamer.drain()
    sfuts[-1].result()
    print(f"StreamServe: {streamer.stats()}")

    # ---- the three TopoScope outputs ------------------------------------
    obs.export_chrome_trace(TRACE_PATH)
    obs.export_prometheus(PROM_PATH)
    events = obs.trace_events()
    print(f"\nwrote {TRACE_PATH} ({len(events)} spans — load it in "
          "https://ui.perfetto.dev)")
    print(f"wrote {PROM_PATH} (Prometheus text exposition)\n")
    # same table as: python -m repro.obs report results/obs/trace_serve.json
    print(format_report(events, top=12))

    # spans also fed the obs.span_seconds histogram, so the trace and the
    # metrics registry agree about where time went
    series = obs.get_instrument("obs.span_seconds").snapshot_series()
    print(f"\nobs.span_seconds: {len(series)} span-name series, "
          f"{sum(s['count'] for s in series.values())} observations")


if __name__ == "__main__":
    main()
