"""End-to-end driver: train a ~100M-parameter qwen3-family LM for a few
hundred steps on the synthetic token stream, with checkpointing and
straggler accounting — the (b) deliverable's end-to-end example.

  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import dataclasses

from repro.configs.registry import get_config
from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/topopipe_100m")
    args = ap.parse_args()

    # ~100M config of the qwen3 family (reduced from the assigned 1.7B):
    # 10L x d768 x ff2560, vocab 32768 -> ~102M params (embeddings tied).
    import repro.configs.registry as reg

    base = get_config("qwen3-1.7b")
    cfg100m = dataclasses.replace(
        base, n_layers=10, d_model=768, d_ff=2560, n_heads=8, n_kv_heads=4,
        d_head=96, vocab_size=32768, attn_chunk=256)
    print(f"~{cfg100m.param_count()/1e6:.0f}M params")

    # route through the trainer with a pinned config
    orig = reg.reduced_config
    reg.reduced_config = lambda arch: cfg100m  # pin for this run
    try:
        out = train("qwen3-1.7b", steps=args.steps, batch=16, seq=512,
                    ckpt_dir=args.ckpt_dir, ckpt_every=100, lr=6e-4,
                    grad_accum=2, log_every=20)
    finally:
        reg.reduced_config = orig
    print(out)
    assert out["final_loss"] < out["first_loss"], "loss did not improve"


if __name__ == "__main__":
    main()
