"""TDA as a model-analysis pass: persistence diagrams of attention graphs.

Train a tiny LM for a few steps, threshold its attention matrices into
graphs, and compute their PDs with the paper's reductions — topology of the
attention pattern as a training diagnostic (DESIGN.md §4).

  PYTHONPATH=src python examples/attention_topology.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import reduced_config
from repro.core.api import reduction_stats, topological_signature
from repro.core.graph import canonicalize
from repro.data.tokens import TokenStream
from repro.models import transformer as tf


def attention_graph(params, cfg, tokens, threshold=0.06):
    """(B, S, S) bool graphs from the average attention of the first block."""
    d = cfg.d_model
    x = params["embed"][tokens].astype(jnp.bfloat16)
    seg = next(iter(params["segments"].values()))
    p = jax.tree.map(lambda a: a[0], seg)  # first layer of the first segment
    from repro.models.layers import rmsnorm, rope_tables

    xn = rmsnorm(x, p["ln1"], cfg.rms_eps)
    b, s, _ = x.shape
    dh = cfg.head_dim
    ap = p["attn"]
    q = (xn @ ap["w_q"].astype(jnp.bfloat16)).reshape(b, s, cfg.q_heads, dh)
    k = (xn @ ap["w_k"].astype(jnp.bfloat16)).reshape(b, s, cfg.kv_heads, dh)
    rep = cfg.q_heads // cfg.kv_heads
    k = jnp.repeat(k, rep, axis=2)
    scores = jnp.einsum("bshd,bthd->bhst", q, k) / jnp.sqrt(dh)
    causal = jnp.tril(jnp.ones((s, s), bool))
    attn = jax.nn.softmax(jnp.where(causal, scores.astype(jnp.float32), -1e9), -1)
    a_mean = attn.mean(axis=1)  # (B, S, S) head-averaged
    sym = jnp.maximum(a_mean, jnp.swapaxes(a_mean, -1, -2))
    return sym > threshold


def main():
    cfg = reduced_config("qwen3-1.7b")
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    stream = TokenStream(vocab_size=cfg.vocab_size, batch=8, seq_len=48)
    tokens = stream.batch_at(jnp.int32(0))["tokens"]

    adj = attention_graph(params, cfg, tokens)
    mask = jnp.ones(adj.shape[:2], bool)
    # filtration: token position (sublevel = prefix growth of the context)
    f = jnp.broadcast_to(jnp.arange(adj.shape[1], dtype=jnp.float32),
                         adj.shape[:2])
    g = canonicalize(adj, mask, f)

    st = reduction_stats(g, dim=1, method="coral")
    coral_red = np.asarray(st.v_reduction_pct())
    print("CoralTDA 2-core reduction %:", coral_red.round(1))
    if (coral_red == 100.0).all():
        print("  -> 2-cores are empty: Thm 2 PROVES PD1 is trivial for every "
              "attention graph without computing any PD.")
    # PD0/PD1 via PrunIT (valid at every dimension, Thm 7)
    d = topological_signature(g, dim=1, method="prunit",
                              edge_cap=256, tri_cap=128)
    print("attention-graph betti_0 (clusters of attended positions):",
          np.asarray(d.betti(0)))
    print("attention-graph PD1 feature count (attention cycles):",
          np.asarray(d.count(1)))


if __name__ == "__main__":
    main()
