"""Graph similarity search over persistence diagrams (TopoMetric + TopoIndex).

Builds a corpus of graphs from three structural families, indexes their
diagrams through ``SimilarityServe`` (the TopoServe-bucketed PD path feeding
a ``TopoIndex``), then queries with fresh samples from each family and
checks that the nearest indexed neighbors come from the query's own family
— the "which known graphs look like this one" serving loop.

  PYTHONPATH=src python examples/similarity_search.py
"""
import numpy as np

import jax

from repro.data import graphs as gdata
from repro.index import TopoIndexConfig
from repro.serve import SimilarityServe

FAMILIES = {
    # sparse rings of cycles vs dense clique-ish vs tree-like
    "ws": lambda k, b: gdata.watts_strogatz(k, b, 24, 20, 4, 0.1),
    "er_dense": lambda k, b: gdata.erdos_renyi(k, b, 24, 20, 0.45),
    "ba_tree": lambda k, b: gdata.barabasi_albert(k, b, 24, 20, 1),
}


def edge_list(g, i):
    adj = np.asarray(g.adj[i])
    n = int(np.asarray(g.mask[i]).sum())
    u, v = np.nonzero(np.triu(adj))
    return list(zip(u.tolist(), v.tolist())), n


def main():
    key = jax.random.PRNGKey(0)
    srv = SimilarityServe(
        index_config=TopoIndexConfig(embedding="both", k=1, n_points=12,
                                     n_dirs=12, res=6),
        default_k=5)

    per_family = 6
    for name, gen in FAMILIES.items():
        key, sub = jax.random.split(key)
        g = gdata.with_degree_filtration(gen(sub, per_family))
        for i in range(per_family):
            edges, n = edge_list(g, i)
            srv.add(edges=edges, n_vertices=n, gid=f"{name}/{i}")

    futs = {}
    for name, gen in FAMILIES.items():
        key, sub = jax.random.split(key)
        g = gdata.with_degree_filtration(gen(sub, 2))
        for i in range(2):
            edges, n = edge_list(g, i)
            futs[f"{name}?{i}"] = srv.submit(edges=edges, n_vertices=n)

    srv.drain()
    print(f"indexed {srv.stats['indexed']} graphs, "
          f"answered {srv.stats['queries']} queries\n")
    correct = total = 0
    for qid, fut in futs.items():
        family = qid.split("?")[0]
        r = fut.result()
        majority = [i.split("/")[0] for i in r.ids[:3]]
        ok = majority.count(family) >= 2
        correct += ok
        total += 1
        top = ", ".join(f"{i} ({d:.1f})" for i, d in
                        zip(r.ids[:3], r.distances[:3]))
        print(f"query {qid:12s} -> {top}   {'OK' if ok else 'MISS'}")
    print(f"\nfamily majority vote: {correct}/{total}")


if __name__ == "__main__":
    main()
