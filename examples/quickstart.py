"""Quickstart: exact persistence diagrams of graphs with the paper's
reductions (CoralTDA Thm 2 + PrunIT Thm 7), end to end in ~20 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import networkx as nx

from repro.core.api import reduce_graphs, reduction_stats, topological_signature
from repro.core.graph import from_networkx
from repro.core.persistence_ref import persistence_diagrams
import numpy as np


def main():
    # a batch of graphs: a 6-cycle (one 1-dim hole), a clique (none),
    # a Petersen graph, and a random ego-net-like graph
    graphs = [
        nx.cycle_graph(6),
        nx.complete_graph(6),
        nx.petersen_graph(),
        nx.barabasi_albert_graph(24, 2, seed=1),
    ]
    g = from_networkx(graphs, n_pad=32)  # degree filtration by default

    # 1. the paper's reductions — how much graph do we NOT have to process?
    st = reduction_stats(g, dim=1, method="both")
    print("vertex reduction % per graph:",
          np.asarray(st.v_reduction_pct()).round(1))
    print("edge   reduction % per graph:",
          np.asarray(st.e_reduction_pct()).round(1))

    # 2. exact PDs on the reduced graphs (identical to the full computation)
    d = topological_signature(g, dim=1, method="both",
                              edge_cap=128, tri_cap=128)
    print("betti_1 per graph:", np.asarray(d.betti(1)))

    # 3. cross-check graph 0 against the NumPy oracle on the UNREDUCED graph
    full = persistence_diagrams(np.asarray(g.adj[0]),
                                np.asarray(g.f[0]),
                                np.asarray(g.mask[0]), max_dim=1)
    from repro.core.persistence_jax import diagrams_to_numpy
    ours = diagrams_to_numpy(d, 0, max_dim=1)
    print("C6 PD1 (reduced pipeline):", ours[1])
    print("C6 PD1 (oracle, full)    :", full[1])
    assert ours[1] == full[1], "Theorem 2/7 exactness violated!"
    print("exactness check passed — reductions are lossless.")


if __name__ == "__main__":
    main()
