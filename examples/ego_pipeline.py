"""The paper's §6.2 workload at framework scale: per-vertex ego-net
persistence diagrams for node classification (TRL-style), with PrunIT
reduction, vmapped over all egos and ready to pjit-shard over a pod mesh.

  PYTHONPATH=src python examples/ego_pipeline.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import make_topo_plan
from repro.data import graphs as gdata
from repro.data.ego import ego_batch
from repro.topo.features import feature_vector


def main():
    # OGB-arxiv-regime host surrogate: preferential-attachment citation graph
    key = jax.random.PRNGKey(0)
    host = gdata.barabasi_albert(key, 1, 256, 256, 3)
    f = host.degrees()[0].astype(jnp.float32)

    # one ego net per vertex -> (256, 48, 48) padded batch
    egos = ego_batch(host.adj[0], f, n_pad=48)
    print(f"{egos.batch} ego nets, padded order {egos.n}")

    # per-ego PD0/PD1 with PrunIT (superlevel, degree filtration: every
    # dominated vertex is removable -> maximal reduction, paper Remark 8).
    # plan->execute: the compiled pipeline is shared with TopoServe /
    # benchmarks through the process-wide plan cache.
    plan = make_topo_plan(dim=1, method="prunit", sublevel=False,
                          edge_cap=160, tri_cap=64)
    t0 = time.time()
    d = plan.execute(egos)
    feats = feature_vector(d, max_dim=plan.dim, res=4)
    jax.block_until_ready(feats)
    # (equivalently in one call: repro.topo.features.signature_features)
    print(f"PDs + features for all egos in {time.time()-t0:.2f}s "
          f"(feature dim {feats.shape[-1]})")

    b0 = np.asarray(d.betti(0))
    print("betti_0 quantiles (ego connectivity):",
          np.quantile(b0, [0.1, 0.5, 0.9]).round(1))
    # downstream: feats feeds any per-node classifier; on a pod mesh the
    # same call is sharded with
    #   jax.jit(pipeline, in_shardings=NamedSharding(mesh, P(("pod","data"))))
    # — see repro/launch/dryrun.py::tda_input_specs (the dry-run proves the
    # 512-chip lowering).


if __name__ == "__main__":
    main()
