"""TopoStream demo: monitoring persistence diagrams of a dynamic network.

Replays a temporal ego-net edge-decay stream (satellite edges dropping and
recovering around a dense core) through a stateful TopoStream session, and
shows the reduction-aware invalidation check answering most ticks from cache:
Theorem 2 says updates outside the (dim+1)-core cannot move PD_dim, and
Theorem 7 says updates confined to dominated vertices cannot move anything —
so the expensive boundary-matrix reduction only runs when a core edge
actually changes.

  PYTHONPATH=src python examples/stream_updates.py
"""
import time

import jax
import numpy as np

from repro.core.delta import delta_step
from repro.data.temporal import ego_decay_stream
from repro.serve import StreamServe
from repro.stream import TopoStream, TopoStreamConfig


def main():
    key = jax.random.PRNGKey(42)
    batch, steps = 8, 60
    g0, deltas = ego_decay_stream(key, batch=batch, n_pad=32, n_core=10,
                                  n_double=6, n_pendant=6, steps=steps,
                                  toggles=1, p_core_edge=0.15)
    cfg = TopoStreamConfig(dim=1, method="both", edge_cap=192, tri_cap=512)

    # ---- direct session -------------------------------------------------
    stream = TopoStream(g0, cfg)
    print(f"watching PD_1 of {batch} dynamic ego nets "
          f"({int(np.asarray(g0.n_vertices())[0])} vertices each), "
          f"{steps} update ticks\n")
    t0 = time.perf_counter()
    for t in range(steps):
        stream.apply(delta_step(deltas, t))
        if (t + 1) % 20 == 0:
            s = stream.stats
            print(f"  tick {t+1:3d}: {s['graph_updates']:4d} updates | "
                  f"{s['hits']} cached ({s['coral_hits']} coral, "
                  f"{s['prunit_hits']} prunit) | {s['recomputes']} recomputed")
    wall = time.perf_counter() - t0
    s = stream.stats
    print(f"\n{s['graph_updates']} graph updates in {wall:.2f}s "
          f"({s['graph_updates']/wall:.0f} updates/s)")
    print(f"skip-rate {stream.skip_rate():.1%} — the theorems proved "
          f"{s['hits']} of {s['graph_updates']} recomputes unnecessary; "
          f"only {s['recomputed_rows']} padded rows re-executed")

    # ---- same stream through the serving layer --------------------------
    server = StreamServe(cfg)
    sid = server.create_session(g0)
    futs = [server.submit(sid, delta_step(deltas, t)) for t in range(steps)]
    server.drain()
    futs[-1].result()
    print(f"\nStreamServe session {sid}: {server.session_stats(sid)}")
    # the invalidation boundary: PD_1 only sees the 2-core, and here it stays
    # small while satellites churn around it — that asymmetry IS the skip-rate
    core_sizes = np.asarray((stream.coreness() >= cfg.dim + 1).sum(-1))
    live = np.asarray(stream.graph.n_vertices())
    print(f"2-core sizes {core_sizes.tolist()} of {live.tolist()} live "
          f"vertices — updates outside never trigger a recompute")


if __name__ == "__main__":
    main()
